//! Typed view of `artifacts/manifest.json` (written by `python -m
//! compile.aot`). The manifest is the only channel through which the L2
//! build-time world describes itself to the L3 runtime: artifact paths,
//! model geometry, the ordered parameter spec (layout + init), and the
//! full input/output signatures.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use crate::util::json::Json;

#[derive(Clone, Debug, PartialEq)]
pub enum Dtype {
    F32,
    I32,
}

impl Dtype {
    fn parse(s: &str) -> Result<Dtype> {
        match s {
            "float32" => Ok(Dtype::F32),
            "int32" => Ok(Dtype::I32),
            _ => bail!("unsupported dtype '{s}'"),
        }
    }
}

#[derive(Clone, Debug)]
pub struct IoSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: Dtype,
}

#[derive(Clone, Debug)]
pub struct ParamSpec {
    pub name: String,
    pub shape: Vec<usize>,
    /// "normal" | "zeros" | "ones"
    pub init: String,
    pub scale: f64,
}

impl ParamSpec {
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }
}

#[derive(Clone, Debug, Default)]
pub struct ModelMeta {
    pub vocab: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub head_dim: usize,
    pub block_size: usize,
    pub topk: usize,
    pub pi_scale: f64,
    pub layer_variants: Vec<String>,
    pub param_count: usize,
}

#[derive(Clone, Debug)]
pub struct Artifact {
    pub name: String,
    pub group: String,
    /// train | train_k | eval | logits | last_logits | kernel_moba | kernel_flash
    pub kind: String,
    pub path: PathBuf,
    pub batch: usize,
    pub seq: usize,
    /// fused optimizer steps per call (1 except kind=train_k)
    pub k_steps: usize,
    pub inputs: Vec<IoSpec>,
    pub outputs: Vec<IoSpec>,
    pub model: ModelMeta,
    pub params: Vec<ParamSpec>,
}

impl Artifact {
    pub fn n_leaves(&self) -> usize {
        self.params.len()
    }

    /// Attention sparsity of the MoBA config at this artifact's seq length
    /// (paper: `1 - block_size * topk / N`).
    pub fn sparsity(&self) -> f64 {
        let bs = self.model.block_size as f64;
        let k = self.model.topk as f64;
        (1.0 - bs * k / self.seq as f64).max(0.0)
    }
}

#[derive(Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub artifacts: BTreeMap<String, Artifact>,
}

fn io_spec(j: &Json) -> Result<IoSpec> {
    Ok(IoSpec {
        name: j.opt("name").map(|n| n.str().unwrap_or("").to_string()).unwrap_or_default(),
        shape: j.get("shape")?.arr()?.iter().map(|x| x.usize()).collect::<Result<_>>()?,
        dtype: Dtype::parse(j.get("dtype")?.str()?)?,
    })
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest> {
        let mpath = dir.join("manifest.json");
        let text = std::fs::read_to_string(&mpath)
            .with_context(|| format!("reading {} — run `make artifacts` first", mpath.display()))?;
        let root = Json::parse(&text).context("parsing manifest.json")?;
        let mut artifacts = BTreeMap::new();
        for a in root.get("artifacts")?.arr()? {
            let name = a.get("name")?.str()?.to_string();
            let model = a.get("model")?;
            let meta = ModelMeta {
                vocab: model.opt("vocab").map(|x| x.usize()).transpose()?.unwrap_or(0),
                d_model: model.opt("d_model").map(|x| x.usize()).transpose()?.unwrap_or(0),
                n_layers: model.opt("n_layers").map(|x| x.usize()).transpose()?.unwrap_or(0),
                n_heads: model
                    .opt("n_heads")
                    .or_else(|| model.opt("heads"))
                    .map(|x| x.usize())
                    .transpose()?
                    .unwrap_or(0),
                head_dim: model.opt("head_dim").map(|x| x.usize()).transpose()?.unwrap_or(0),
                block_size: model.opt("block_size").map(|x| x.usize()).transpose()?.unwrap_or(0),
                topk: model.opt("topk").map(|x| x.usize()).transpose()?.unwrap_or(0),
                pi_scale: model.opt("pi_scale").map(|x| x.num()).transpose()?.unwrap_or(1.0),
                layer_variants: model
                    .opt("layer_variants")
                    .map(|v| -> Result<Vec<String>> {
                        v.arr()?.iter().map(|x| Ok(x.str()?.to_string())).collect()
                    })
                    .transpose()?
                    .unwrap_or_default(),
                param_count: model.opt("param_count").map(|x| x.usize()).transpose()?.unwrap_or(0),
            };
            let params = match a.opt("params") {
                Some(ps) => ps
                    .arr()?
                    .iter()
                    .map(|p| -> Result<ParamSpec> {
                        Ok(ParamSpec {
                            name: p.get("name")?.str()?.to_string(),
                            shape: p
                                .get("shape")?
                                .arr()?
                                .iter()
                                .map(|x| x.usize())
                                .collect::<Result<_>>()?,
                            init: p.get("init")?.str()?.to_string(),
                            scale: p.get("scale")?.num()?,
                        })
                    })
                    .collect::<Result<_>>()?,
                None => Vec::new(),
            };
            let art = Artifact {
                name: name.clone(),
                group: a.get("group")?.str()?.to_string(),
                kind: a.get("kind")?.str()?.to_string(),
                path: dir.join(a.get("path")?.str()?),
                batch: a.get("batch")?.usize()?,
                seq: a.get("seq")?.usize()?,
                k_steps: a.opt("k_steps").map(|x| x.usize()).transpose()?.unwrap_or(1),
                inputs: a.get("inputs")?.arr()?.iter().map(io_spec).collect::<Result<_>>()?,
                outputs: a.get("outputs")?.arr()?.iter().map(io_spec).collect::<Result<_>>()?,
                model: meta,
                params,
            };
            artifacts.insert(name, art);
        }
        Ok(Manifest { dir: dir.to_path_buf(), artifacts })
    }

    pub fn get(&self, name: &str) -> Result<&Artifact> {
        self.artifacts.get(name).ok_or_else(|| {
            anyhow!(
                "artifact '{name}' not in manifest ({} known); run `make artifacts`",
                self.artifacts.len()
            )
        })
    }

    pub fn by_group(&self, group: &str) -> Vec<&Artifact> {
        self.artifacts.values().filter(|a| a.group == group).collect()
    }
}

/// Validate internal consistency of an artifact: the declared inputs must
/// match the train/eval conventions for its kind.
pub fn validate(art: &Artifact) -> Result<()> {
    let n = art.n_leaves();
    let expect_inputs = match art.kind.as_str() {
        "train" | "train_k" => 3 * n + 4,
        "eval" => n + 2,
        "logits" | "last_logits" => n + 1,
        "kernel_moba" | "kernel_flash" => 3,
        k => bail!("unknown artifact kind '{k}'"),
    };
    if art.inputs.len() != expect_inputs {
        bail!(
            "artifact '{}' kind={} declares {} inputs, expected {}",
            art.name, art.kind, art.inputs.len(), expect_inputs
        );
    }
    if art.kind == "train" || art.kind == "train_k" {
        let expect_outputs = 3 * n + 1;
        if art.outputs.len() != expect_outputs {
            bail!(
                "artifact '{}' declares {} outputs, expected {}",
                art.name, art.outputs.len(), expect_outputs
            );
        }
        // leaf shapes must line up across params/m/v blocks
        for (i, p) in art.params.iter().enumerate() {
            for block in 0..3 {
                let spec = &art.inputs[block * n + i];
                if spec.shape != p.shape {
                    bail!(
                        "artifact '{}': input {} shape {:?} != param '{}' shape {:?}",
                        art.name, block * n + i, spec.shape, p.name, p.shape
                    );
                }
            }
        }
    }
    if !art.path.exists() {
        bail!("artifact file missing: {}", art.path.display());
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn manifest_dir() -> PathBuf {
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    /// Artifacts are a build product (`make artifacts`); on boxes without
    /// them these tests skip instead of failing.
    fn manifest_or_skip() -> Option<Manifest> {
        match Manifest::load(&manifest_dir()) {
            Ok(m) => Some(m),
            Err(_) => {
                eprintln!("artifacts missing — run `make artifacts` (skipping)");
                None
            }
        }
    }

    #[test]
    fn loads_real_manifest() {
        let Some(m) = manifest_or_skip() else { return };
        assert!(m.artifacts.len() >= 7, "expected at least the core group");
        let q = m.get("quickstart_train").unwrap();
        assert_eq!(q.kind, "train");
        assert!(q.model.param_count > 0);
        assert_eq!(q.params.len(), q.n_leaves());
    }

    #[test]
    fn validates_core_artifacts() {
        let Some(m) = manifest_or_skip() else { return };
        for a in m.by_group("core") {
            validate(a).unwrap_or_else(|e| panic!("{}: {e}", a.name));
        }
    }

    #[test]
    fn sparsity_formula() {
        let Some(m) = manifest_or_skip() else { return };
        let q = m.get("quickstart_train").unwrap();
        // quickstart: seq 256, block 32, topk 2 -> 1 - 64/256 = 0.75
        assert!((q.sparsity() - 0.75).abs() < 1e-9);
    }

    #[test]
    fn unknown_artifact_is_error() {
        let Some(m) = manifest_or_skip() else { return };
        assert!(m.get("nope").is_err());
    }
}
