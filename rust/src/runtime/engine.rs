//! PJRT execution engine: loads AOT HLO-text artifacts and runs them.
//!
//! This is the only module that touches the `xla` crate, and it only
//! compiles with the `xla` feature. Pattern (see
//! /opt/xla-example/load_hlo): `HloModuleProto::from_text_file` →
//! `XlaComputation::from_proto` → `PjRtClient::compile` → `execute`.
//! Executables are compiled once and cached per artifact name.
//!
//! All state crosses the boundary as host `Tensor`s (`ModelState` itself
//! lives in `runtime::state`, which is feature-independent). The AOT
//! graphs are lowered with `return_tuple=True`, so every execution yields
//! one tuple literal which is decomposed back into leaves here.

use std::cell::RefCell;
use std::collections::HashMap;
use std::path::Path;
use std::rc::Rc;
use std::time::Instant;

use anyhow::{bail, Context, Result};

use super::manifest::Manifest;
use super::state::ModelState;
use crate::tensor::{IntTensor, Tensor};

pub struct Engine {
    client: xla::PjRtClient,
    pub manifest: Manifest,
    cache: RefCell<HashMap<String, Rc<xla::PjRtLoadedExecutable>>>,
    /// cumulative host<->device marshalling time (perf accounting)
    pub marshal_secs: RefCell<f64>,
    /// cumulative execute time
    pub exec_secs: RefCell<f64>,
}

impl Engine {
    pub fn new(artifacts_dir: &Path) -> Result<Engine> {
        let manifest = Manifest::load(artifacts_dir)?;
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Engine {
            client,
            manifest,
            cache: RefCell::new(HashMap::new()),
            marshal_secs: RefCell::new(0.0),
            exec_secs: RefCell::new(0.0),
        })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Compile (or fetch from cache) the executable for an artifact.
    pub fn load(&self, name: &str) -> Result<Rc<xla::PjRtLoadedExecutable>> {
        if let Some(exe) = self.cache.borrow().get(name) {
            return Ok(exe.clone());
        }
        let art = self.manifest.get(name)?;
        let t0 = Instant::now();
        let proto = xla::HloModuleProto::from_text_file(&art.path)
            .with_context(|| format!("parsing HLO text {}", art.path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = Rc::new(
            self.client
                .compile(&comp)
                .with_context(|| format!("XLA compile of '{name}'"))?,
        );
        let dt = t0.elapsed().as_secs_f64();
        if dt > 1.0 {
            eprintln!("[engine] compiled {name} in {dt:.1}s");
        }
        self.cache.borrow_mut().insert(name.to_string(), exe.clone());
        Ok(exe)
    }

    /// Execute an artifact on already-marshalled literals; decompose the
    /// result tuple.
    pub fn run(&self, name: &str, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let art = self.manifest.get(name)?;
        if inputs.len() != art.inputs.len() {
            bail!(
                "artifact '{name}' expects {} inputs, got {}",
                art.inputs.len(),
                inputs.len()
            );
        }
        let exe = self.load(name)?;
        let t0 = Instant::now();
        let result = exe.execute::<xla::Literal>(inputs)?;
        *self.exec_secs.borrow_mut() += t0.elapsed().as_secs_f64();
        let t1 = Instant::now();
        let tuple = result[0][0].to_literal_sync()?;
        let parts = tuple.to_tuple()?;
        *self.marshal_secs.borrow_mut() += t1.elapsed().as_secs_f64();
        if parts.len() != art.outputs.len() {
            bail!(
                "artifact '{name}' returned {} outputs, manifest says {}",
                parts.len(),
                art.outputs.len()
            );
        }
        Ok(parts)
    }

    /// Execute with host tensors in / host tensors out (f32 outputs only).
    pub fn run_tensors(&self, name: &str, inputs: &[Input]) -> Result<Vec<Tensor>> {
        let t0 = Instant::now();
        let lits: Vec<xla::Literal> =
            inputs.iter().map(to_literal).collect::<Result<_>>()?;
        *self.marshal_secs.borrow_mut() += t0.elapsed().as_secs_f64();
        let outs = self.run(name, &lits)?;
        let t1 = Instant::now();
        let tensors = outs.iter().map(literal_to_tensor).collect::<Result<_>>()?;
        *self.marshal_secs.borrow_mut() += t1.elapsed().as_secs_f64();
        Ok(tensors)
    }

    pub fn reset_timers(&self) {
        *self.marshal_secs.borrow_mut() = 0.0;
        *self.exec_secs.borrow_mut() = 0.0;
    }
}

/// A host-side input value (f32 or i32 tensor).
pub enum Input<'a> {
    F(&'a Tensor),
    I(&'a IntTensor),
}

pub fn to_literal(input: &Input) -> Result<xla::Literal> {
    match input {
        Input::F(t) => {
            if t.shape.is_empty() {
                return Ok(xla::Literal::scalar(t.data[0]));
            }
            let dims: Vec<i64> = t.shape.iter().map(|&d| d as i64).collect();
            Ok(xla::Literal::vec1(&t.data).reshape(&dims)?)
        }
        Input::I(t) => {
            if t.shape.is_empty() {
                return Ok(xla::Literal::scalar(t.data[0]));
            }
            let dims: Vec<i64> = t.shape.iter().map(|&d| d as i64).collect();
            Ok(xla::Literal::vec1(&t.data).reshape(&dims)?)
        }
    }
}

pub fn literal_to_tensor(lit: &xla::Literal) -> Result<Tensor> {
    let shape = lit.array_shape()?;
    let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
    let data = lit.to_vec::<f32>()?;
    Tensor::from_vec(&dims, data)
}

pub fn literal_to_int_tensor(lit: &xla::Literal) -> Result<IntTensor> {
    let shape = lit.array_shape()?;
    let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
    let data = lit.to_vec::<i32>()?;
    IntTensor::from_vec(&dims, data)
}

// ---------------------------------------------------------------------------
// high-level drivers for each artifact kind
// ---------------------------------------------------------------------------

impl Engine {
    /// One optimizer step. Mutates `state` in place; returns the batch loss.
    pub fn train_step(
        &self,
        name: &str,
        state: &mut ModelState,
        lr: f32,
        tokens: &IntTensor,
        mask: &Tensor,
    ) -> Result<f32> {
        let art = self.manifest.get(name)?;
        if art.kind != "train" {
            bail!("'{name}' is kind={}, not train", art.kind);
        }
        if !state.compatible_with(art) {
            bail!("state geometry does not match artifact '{name}'");
        }
        state.step += 1;
        let step_t = Tensor::scalar(state.step as f32);
        let lr_t = Tensor::scalar(lr);
        let mut inputs: Vec<Input> = Vec::with_capacity(3 * state.params.len() + 4);
        inputs.extend(state.params.iter().map(Input::F));
        inputs.extend(state.m.iter().map(Input::F));
        inputs.extend(state.v.iter().map(Input::F));
        inputs.push(Input::F(&step_t));
        inputs.push(Input::F(&lr_t));
        inputs.push(Input::I(tokens));
        inputs.push(Input::F(mask));
        let outs = self.run_tensors(name, &inputs)?;
        let n = state.params.len();
        let loss = outs[3 * n].data[0];
        let mut it = outs.into_iter();
        for p in state.params.iter_mut() {
            *p = it.next().unwrap();
        }
        for m in state.m.iter_mut() {
            *m = it.next().unwrap();
        }
        for v in state.v.iter_mut() {
            *v = it.next().unwrap();
        }
        Ok(loss)
    }

    /// K fused optimizer steps in one PJRT call (kind=train_k): the §Perf
    /// path that amortizes the host<->device state roundtrip K-fold.
    /// `lrs` has one LR per fused step; `tokens` is `[K, B, S]`, `masks`
    /// `[K, B, S-1]`. Returns the K per-step losses.
    pub fn train_k_steps(
        &self,
        name: &str,
        state: &mut ModelState,
        lrs: &[f32],
        tokens: &IntTensor,
        masks: &Tensor,
    ) -> Result<Vec<f32>> {
        let art = self.manifest.get(name)?;
        if art.kind != "train_k" {
            bail!("'{name}' is kind={}, not train_k", art.kind);
        }
        let k = art.k_steps;
        if lrs.len() != k || tokens.shape.first() != Some(&k) {
            bail!("expected {k} fused steps, got lrs={} tokens={:?}", lrs.len(), tokens.shape);
        }
        if !state.compatible_with(art) {
            bail!("state geometry does not match artifact '{name}'");
        }
        let step_t = Tensor::scalar(state.step as f32 + 1.0);
        let lr_t = Tensor::from_vec(&[k], lrs.to_vec())?;
        state.step += k as u64;
        let mut inputs: Vec<Input> = Vec::with_capacity(3 * state.params.len() + 4);
        inputs.extend(state.params.iter().map(Input::F));
        inputs.extend(state.m.iter().map(Input::F));
        inputs.extend(state.v.iter().map(Input::F));
        inputs.push(Input::F(&step_t));
        inputs.push(Input::F(&lr_t));
        inputs.push(Input::I(tokens));
        inputs.push(Input::F(masks));
        let outs = self.run_tensors(name, &inputs)?;
        let n = state.params.len();
        let losses = outs[3 * n].data.clone();
        let mut it = outs.into_iter();
        for p in state.params.iter_mut() {
            *p = it.next().unwrap();
        }
        for m in state.m.iter_mut() {
            *m = it.next().unwrap();
        }
        for v in state.v.iter_mut() {
            *v = it.next().unwrap();
        }
        Ok(losses)
    }

    /// Per-position losses `[B, S-1]` (masked positions contribute 0).
    pub fn eval_losses(
        &self,
        name: &str,
        params: &[Tensor],
        tokens: &IntTensor,
        mask: &Tensor,
    ) -> Result<Tensor> {
        let art = self.manifest.get(name)?;
        if art.kind != "eval" {
            bail!("'{name}' is kind={}, not eval", art.kind);
        }
        let mut inputs: Vec<Input> = params.iter().map(Input::F).collect();
        inputs.push(Input::I(tokens));
        inputs.push(Input::F(mask));
        let mut outs = self.run_tensors(name, &inputs)?;
        Ok(outs.remove(0))
    }

    /// Full logits `[B, S, vocab]` (kind=logits) or `[B, vocab]`
    /// (kind=last_logits).
    pub fn logits(
        &self,
        name: &str,
        params: &[Tensor],
        tokens: &IntTensor,
    ) -> Result<Tensor> {
        let art = self.manifest.get(name)?;
        if art.kind != "logits" && art.kind != "last_logits" {
            bail!("'{name}' is kind={}, not logits", art.kind);
        }
        let mut inputs: Vec<Input> = params.iter().map(Input::F).collect();
        inputs.push(Input::I(tokens));
        let mut outs = self.run_tensors(name, &inputs)?;
        Ok(outs.remove(0))
    }

    /// Run a standalone L1 kernel artifact: q,k,v `[N,H,D]` -> out `[N,H,D]`.
    pub fn kernel(&self, name: &str, q: &Tensor, k: &Tensor, v: &Tensor) -> Result<Tensor> {
        let art = self.manifest.get(name)?;
        if !art.kind.starts_with("kernel_") {
            bail!("'{name}' is kind={}, not a kernel", art.kind);
        }
        let mut outs =
            self.run_tensors(name, &[Input::F(q), Input::F(k), Input::F(v)])?;
        Ok(outs.remove(0))
    }
}
