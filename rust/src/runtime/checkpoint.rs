//! Checkpoint I/O for `ModelState`: a simple self-describing binary
//! format (magic + JSON header + raw f32 little-endian payload).
//!
//! Used by the training loop for resumable runs and by the experiment
//! harnesses to hand trained models to the eval/serve paths.

use std::io::{Read, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

use super::state::ModelState;
use crate::tensor::Tensor;
use crate::util::json::{arr, num, obj, s, Json};

const MAGIC: &[u8; 8] = b"MOBACKP1";

pub fn save(state: &ModelState, path: &Path) -> Result<()> {
    if let Some(parent) = path.parent() {
        std::fs::create_dir_all(parent)?;
    }
    let header = obj(vec![
        ("step", num(state.step as f64)),
        ("n_leaves", num(state.params.len() as f64)),
        (
            "shapes",
            arr(state
                .params
                .iter()
                .map(|t| arr(t.shape.iter().map(|&d| num(d as f64)).collect()))
                .collect()),
        ),
        ("format", s("f32le:params,m,v")),
    ])
    .to_string();

    let tmp = path.with_extension("tmp");
    {
        let mut f = std::io::BufWriter::new(
            std::fs::File::create(&tmp)
                .with_context(|| format!("creating {}", tmp.display()))?,
        );
        f.write_all(MAGIC)?;
        f.write_all(&(header.len() as u64).to_le_bytes())?;
        f.write_all(header.as_bytes())?;
        for group in [&state.params, &state.m, &state.v] {
            for t in group {
                for &x in &t.data {
                    f.write_all(&x.to_le_bytes())?;
                }
            }
        }
        f.flush()?;
    }
    std::fs::rename(&tmp, path)?; // atomic publish
    Ok(())
}

pub fn load(path: &Path) -> Result<ModelState> {
    let mut f = std::io::BufReader::new(
        std::fs::File::open(path).with_context(|| format!("opening {}", path.display()))?,
    );
    let mut magic = [0u8; 8];
    f.read_exact(&mut magic)?;
    if &magic != MAGIC {
        bail!("{} is not a MoBA checkpoint", path.display());
    }
    let mut len8 = [0u8; 8];
    f.read_exact(&mut len8)?;
    let hlen = u64::from_le_bytes(len8) as usize;
    let mut hbuf = vec![0u8; hlen];
    f.read_exact(&mut hbuf)?;
    let header = Json::parse(std::str::from_utf8(&hbuf)?)?;
    let step = header.get("step")?.usize()? as u64;
    let shapes: Vec<Vec<usize>> = header
        .get("shapes")?
        .arr()?
        .iter()
        .map(|sh| -> Result<Vec<usize>> { sh.arr()?.iter().map(|d| d.usize()).collect() })
        .collect::<Result<_>>()?;

    let mut read_group = |shapes: &[Vec<usize>]| -> Result<Vec<Tensor>> {
        let mut out = Vec::with_capacity(shapes.len());
        for sh in shapes {
            let n: usize = sh.iter().product();
            let mut buf = vec![0u8; n * 4];
            f.read_exact(&mut buf)?;
            let data: Vec<f32> = buf
                .chunks_exact(4)
                .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                .collect();
            out.push(Tensor::from_vec(sh, data)?);
        }
        Ok(out)
    };

    let params = read_group(&shapes)?;
    let m = read_group(&shapes)?;
    let v = read_group(&shapes)?;
    Ok(ModelState { params, m, v, step })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn tiny_state() -> ModelState {
        let mut rng = Rng::new(1);
        let mk = |shape: &[usize], rng: &mut Rng| {
            let n: usize = shape.iter().product();
            Tensor::from_vec(shape, (0..n).map(|_| rng.normal_f32(1.0)).collect()).unwrap()
        };
        let params = vec![mk(&[4, 3], &mut rng), mk(&[3], &mut rng)];
        let m = vec![mk(&[4, 3], &mut rng), mk(&[3], &mut rng)];
        let v = vec![mk(&[4, 3], &mut rng), mk(&[3], &mut rng)];
        ModelState { params, m, v, step: 17 }
    }

    #[test]
    fn roundtrip() {
        let dir = std::env::temp_dir().join("moba_ckpt_test");
        let path = dir.join("state.ckpt");
        let state = tiny_state();
        save(&state, &path).unwrap();
        let loaded = load(&path).unwrap();
        assert_eq!(loaded.step, 17);
        assert_eq!(loaded.params, state.params);
        assert_eq!(loaded.m, state.m);
        assert_eq!(loaded.v, state.v);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn rejects_non_checkpoint() {
        let dir = std::env::temp_dir().join("moba_ckpt_test2");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bogus.ckpt");
        std::fs::write(&path, b"not a checkpoint at all").unwrap();
        assert!(load(&path).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }
}
