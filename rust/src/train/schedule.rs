//! Learning-rate schedule: linear warmup + cosine decay to a floor.
//! Lives in L3 (the AOT train graphs take `lr` as an input each step).

#[derive(Clone, Debug)]
pub struct LrSchedule {
    pub base: f64,
    pub warmup_steps: u64,
    pub total_steps: u64,
    pub min_frac: f64,
}

impl LrSchedule {
    pub fn new(base: f64, total_steps: u64, warmup_frac: f64, min_frac: f64) -> LrSchedule {
        let warmup_steps = ((total_steps as f64) * warmup_frac).round() as u64;
        LrSchedule { base, warmup_steps, total_steps, min_frac }
    }

    /// LR at 0-based step.
    pub fn at(&self, step: u64) -> f64 {
        if self.total_steps == 0 {
            return self.base;
        }
        if step < self.warmup_steps {
            return self.base * (step + 1) as f64 / self.warmup_steps.max(1) as f64;
        }
        let decay_span = (self.total_steps - self.warmup_steps).max(1) as f64;
        let t = ((step - self.warmup_steps) as f64 / decay_span).min(1.0);
        let cos = 0.5 * (1.0 + (std::f64::consts::PI * t).cos());
        let floor = self.base * self.min_frac;
        floor + (self.base - floor) * cos
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn warmup_ramps_linearly() {
        let s = LrSchedule::new(1.0, 100, 0.1, 0.0);
        assert!((s.at(0) - 0.1).abs() < 1e-12);
        assert!((s.at(4) - 0.5).abs() < 1e-12);
        assert!((s.at(9) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn cosine_decays_to_floor() {
        let s = LrSchedule::new(2.0, 100, 0.1, 0.05);
        assert!((s.at(10) - 2.0).abs() < 1e-9);
        let end = s.at(99);
        assert!(end >= 2.0 * 0.05 - 1e-9);
        assert!(end < 0.2, "end={end}");
        // monotone decreasing after warmup
        let mut prev = s.at(10);
        for step in 11..100 {
            let cur = s.at(step);
            assert!(cur <= prev + 1e-12);
            prev = cur;
        }
    }

    #[test]
    fn past_end_clamps() {
        let s = LrSchedule::new(1.0, 10, 0.0, 0.1);
        assert!((s.at(1000) - 0.1).abs() < 1e-9);
    }

    #[test]
    fn zero_warmup_safe() {
        let s = LrSchedule::new(1.0, 10, 0.0, 0.0);
        assert!(s.at(0) > 0.0);
    }
}
