//! Training: LR schedules and the stage-scheduled training loop.

pub mod schedule;
pub mod trainer;

pub use schedule::LrSchedule;
pub use trainer::{RunSummary, StepInfo, Trainer};
