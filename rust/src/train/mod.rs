//! Training: LR schedules and (behind the `xla` feature) the
//! stage-scheduled training loop over PJRT executables.

pub mod schedule;
#[cfg(feature = "xla")]
pub mod trainer;

pub use schedule::LrSchedule;
#[cfg(feature = "xla")]
pub use trainer::{RunSummary, StepInfo, Trainer};
