//! The training loop: drives AOT train-step executables over a stage
//! schedule, with the LR policy, batch sourcing, loss logging and
//! checkpointing owned here in L3.

use std::time::Instant;

use anyhow::{bail, Result};

use crate::coordinator::StageSchedule;
use crate::runtime::{Engine, ModelState};
use crate::tensor::{IntTensor, Tensor};

use super::schedule::LrSchedule;

/// Per-step record handed to the observer callback.
#[derive(Clone, Debug)]
pub struct StepInfo {
    pub step: u64,
    pub artifact: String,
    pub lr: f64,
    pub loss: f32,
    pub step_secs: f64,
}

/// Summary of a completed run.
#[derive(Clone, Debug)]
pub struct RunSummary {
    pub steps: u64,
    pub final_loss: f32,
    pub mean_last_quarter: f64,
    pub total_secs: f64,
    pub losses: Vec<f32>,
}

pub struct Trainer<'e> {
    pub engine: &'e Engine,
    pub state: ModelState,
    pub schedule: StageSchedule,
    pub lr: LrSchedule,
}

impl<'e> Trainer<'e> {
    /// Build a trainer whose state is initialized from the first stage's
    /// artifact spec.
    pub fn new(
        engine: &'e Engine,
        schedule: StageSchedule,
        lr: LrSchedule,
        seed: u64,
    ) -> Result<Trainer<'e>> {
        let first = schedule
            .stage_list()
            .first()
            .ok_or_else(|| anyhow::anyhow!("empty stage schedule"))?;
        let art = engine.manifest.get(&first.artifact)?;
        if art.kind != "train" {
            bail!("first stage artifact '{}' is not a train artifact", first.artifact);
        }
        let state = ModelState::init(art, seed)?;
        Ok(Trainer { engine, state, schedule, lr })
    }

    /// Resume from an existing state (continual pre-training stages).
    pub fn with_state(
        engine: &'e Engine,
        state: ModelState,
        schedule: StageSchedule,
        lr: LrSchedule,
    ) -> Trainer<'e> {
        Trainer { engine, state, schedule, lr }
    }

    /// Run the full schedule. `batches(step)` supplies (tokens, mask);
    /// `observer` sees every step (logging, CSV, eval triggers).
    pub fn run(
        &mut self,
        mut batches: impl FnMut(u64) -> (IntTensor, Tensor),
        mut observer: impl FnMut(&StepInfo),
    ) -> Result<RunSummary> {
        let total = self.schedule.total_steps();
        let mut losses = Vec::with_capacity(total as usize);
        let t_run = Instant::now();
        for step in 0..total {
            let artifact = self
                .schedule
                .artifact_for(step)
                .expect("step within total")
                .to_string();
            let lr = self.lr.at(step);
            let (tokens, mask) = batches(step);
            let t0 = Instant::now();
            let loss = self
                .engine
                .train_step(&artifact, &mut self.state, lr as f32, &tokens, &mask)?;
            if !loss.is_finite() {
                bail!("non-finite loss {loss} at step {step} (artifact {artifact})");
            }
            losses.push(loss);
            observer(&StepInfo {
                step,
                artifact,
                lr,
                loss,
                step_secs: t0.elapsed().as_secs_f64(),
            });
        }
        let q = losses.len().max(4) / 4;
        let last_q = &losses[losses.len().saturating_sub(q)..];
        Ok(RunSummary {
            steps: total,
            final_loss: *losses.last().unwrap_or(&f32::NAN),
            mean_last_quarter: last_q.iter().map(|&x| x as f64).sum::<f64>()
                / last_q.len().max(1) as f64,
            total_secs: t_run.elapsed().as_secs_f64(),
            losses,
        })
    }
}
