//! Cross-backend conformance: every `AttentionBackend` kind is driven
//! through the same checks from ONE registry (`common::ALL_BACKENDS`):
//!
//! 1. the golden append-one-token loop — decode at every (ragged) length
//!    must reproduce the batch oracle's last row bit-for-bit;
//! 2. property invariants — prefill/decode boundary invisibility, convex
//!    output rows, reset-then-reuse lifecycle, gate exposure;
//! 3. workers=1 vs many bitwise equality on prefill and decode;
//! 4. served-token agreement across backends of the same math.
//!
//! A future backend (per-head MoA configs, SIMD kernels, ...) inherits
//! all of this by adding one constructor entry to `common::ALL_BACKENDS`.

mod common;

use common::{
    build, oracle, prefix, rand_t, row, ALL_BACKENDS, EVICTABLE_BACKENDS, SPARSE_BACKENDS,
    SWAPPABLE_BACKENDS,
};
use moba::serve::{LayerKind, ServeCfg, ServeEngine, ToyModel};
use moba::sparse::BackendKind;
use moba::tensor::Tensor;

const H: usize = 2;
const D: usize = 8;
const BS: usize = 16;
const TOPK: usize = 2;

#[test]
fn forward_matches_oracle_bitwise() {
    let q = rand_t(&[48, H, D], 1);
    let k = rand_t(&[48, H, D], 2);
    let v = rand_t(&[48, H, D], 3);
    for &kind in ALL_BACKENDS {
        let b = build(kind, H, D, BS, TOPK, 1);
        let want = oracle(kind, &q, &k, &v, BS, TOPK);
        assert_eq!(b.forward(&q, &k, &v).data, want.data, "{}", b.name());
    }
}

#[test]
fn golden_append_one_token_loop() {
    // n = 41 is deliberately ragged: mid-block lengths exercise the
    // partial current block at every step
    let n = 41;
    let q = rand_t(&[n, H, D], 4);
    let k = rand_t(&[n, H, D], 5);
    let v = rand_t(&[n, H, D], 6);
    for &kind in ALL_BACKENDS {
        let mut b = build(kind, H, D, BS, TOPK, 1);
        for t in 0..n {
            let got = b.decode(row(&q, t), row(&k, t), row(&v, t));
            let (qp, kp, vp) = (prefix(&q, t + 1), prefix(&k, t + 1), prefix(&v, t + 1));
            let want = oracle(kind, &qp, &kp, &vp, BS, TOPK);
            assert_eq!(got.as_slice(), row(&want, t), "{} t={t}", b.name());
        }
        assert_eq!(b.seq_len(), n, "{}", b.name());
    }
}

#[test]
fn prefill_decode_boundary_is_invisible() {
    let (n, split) = (40, 25); // ragged boundary mid-block
    let q = rand_t(&[n, H, D], 7);
    let k = rand_t(&[n, H, D], 8);
    let v = rand_t(&[n, H, D], 9);
    for &kind in ALL_BACKENDS {
        let mut a = build(kind, H, D, BS, TOPK, 1);
        let out = a.prefill(&prefix(&q, split), &prefix(&k, split), &prefix(&v, split));
        assert_eq!(out.shape, vec![split, H, D], "{}", a.name());
        let mut b = build(kind, H, D, BS, TOPK, 1);
        for t in 0..split {
            b.decode(row(&q, t), row(&k, t), row(&v, t));
        }
        for t in split..n {
            let ra = a.decode(row(&q, t), row(&k, t), row(&v, t));
            let rb = b.decode(row(&q, t), row(&k, t), row(&v, t));
            assert_eq!(ra, rb, "{} t={t}", a.name());
        }
    }
}

#[test]
fn output_rows_are_convex_combinations() {
    // v constant 1 → every attention output must be exactly ~1
    let q = rand_t(&[32, H, D], 10);
    let k = rand_t(&[32, H, D], 11);
    let v = Tensor::ones(&[32, H, D]);
    for &kind in ALL_BACKENDS {
        let mut b = build(kind, H, D, BS, TOPK, 1);
        let out = b.prefill(&q, &k, &v);
        for &x in &out.data {
            assert!((x - 1.0).abs() < 1e-4, "{}: not convex: {x}", b.name());
        }
    }
}

#[test]
fn workers_do_not_change_prefill_or_decode() {
    let n = 37;
    let q = rand_t(&[n, H, D], 12);
    let k = rand_t(&[n, H, D], 13);
    let v = rand_t(&[n, H, D], 14);
    let (qe, ke, ve) = (rand_t(&[1, H, D], 15), rand_t(&[1, H, D], 16), rand_t(&[1, H, D], 17));
    for &kind in ALL_BACKENDS {
        let mut one = build(kind, H, D, BS, TOPK, 1);
        let mut many = build(kind, H, D, BS, TOPK, 4);
        assert_eq!(
            one.prefill(&q, &k, &v).data,
            many.prefill(&q, &k, &v).data,
            "{} prefill",
            one.name()
        );
        assert_eq!(
            one.decode(&qe.data, &ke.data, &ve.data),
            many.decode(&qe.data, &ke.data, &ve.data),
            "{} decode",
            one.name()
        );
    }
}

#[test]
fn reset_then_reuse_reproduces_first_run() {
    let q = rand_t(&[24, H, D], 18);
    let k = rand_t(&[24, H, D], 19);
    let v = rand_t(&[24, H, D], 20);
    for &kind in ALL_BACKENDS {
        let mut b = build(kind, H, D, BS, TOPK, 1);
        let first = b.prefill(&q, &k, &v);
        assert_eq!(b.seq_len(), 24, "{}", b.name());
        b.reset();
        assert_eq!(b.seq_len(), 0, "{}", b.name());
        assert_eq!(b.prefill(&q, &k, &v).data, first.data, "{} reuse", b.name());
    }
}

#[test]
fn gate_exposed_iff_sparse() {
    let q = rand_t(&[32, H, D], 21);
    let k = rand_t(&[32, H, D], 22);
    for &kind in ALL_BACKENDS {
        let b = build(kind, H, D, BS, TOPK, 1);
        let sparse = SPARSE_BACKENDS.contains(&kind);
        assert_eq!(b.gate(&q, &k).is_some(), sparse, "{}", b.name());
        if let Some(g) = b.gate(&q, &k) {
            assert_eq!(g.n_blocks, 2, "{}", b.name());
        }
    }
}

#[test]
fn evict_supported_iff_registered() {
    let q = rand_t(&[24, H, D], 23);
    let k = rand_t(&[24, H, D], 24);
    let v = rand_t(&[24, H, D], 25);
    for &kind in ALL_BACKENDS {
        let mut b = build(kind, H, D, BS, TOPK, 1);
        b.prefill(&q, &k, &v);
        let evictable = EVICTABLE_BACKENDS.contains(&kind);
        match b.evict() {
            Ok(freed) => {
                assert!(evictable, "{} evicted but is not registered evictable", b.name());
                assert!(freed > 0, "{}: eviction reclaimed nothing", b.name());
                assert_eq!(b.seq_len(), 0, "{}", b.name());
            }
            Err(_) => {
                assert!(!evictable, "{} is registered evictable but refused", b.name());
                assert_eq!(b.seq_len(), 24, "{}: failed evict must not corrupt", b.name());
            }
        }
    }
}

#[test]
fn evict_then_reingest_matches_never_evicted_twin() {
    // the re-prefill resume contract at the backend level: evict
    // mid-decode, re-ingest the same (ragged) stream, keep decoding —
    // every subsequent row must equal the never-evicted twin's, bitwise
    let (n, split) = (41, 23);
    let q = rand_t(&[n, H, D], 26);
    let k = rand_t(&[n, H, D], 27);
    let v = rand_t(&[n, H, D], 28);
    for &kind in EVICTABLE_BACKENDS {
        let mut twin = build(kind, H, D, BS, TOPK, 1);
        let mut victim = build(kind, H, D, BS, TOPK, 1);
        for t in 0..split {
            let a = victim.decode(row(&q, t), row(&k, t), row(&v, t));
            let b = twin.decode(row(&q, t), row(&k, t), row(&v, t));
            assert_eq!(a, b, "{} t={t}", twin.name());
        }
        victim.evict().unwrap();
        victim.prefill(&prefix(&q, split), &prefix(&k, split), &prefix(&v, split));
        assert_eq!(victim.seq_len(), split, "{}", victim.name());
        for t in split..n {
            let a = victim.decode(row(&q, t), row(&k, t), row(&v, t));
            let b = twin.decode(row(&q, t), row(&k, t), row(&v, t));
            assert_eq!(a, b, "{} post-resume t={t}", twin.name());
        }
    }
}

#[test]
fn swap_supported_iff_registered() {
    let q = rand_t(&[24, H, D], 29);
    let k = rand_t(&[24, H, D], 30);
    let v = rand_t(&[24, H, D], 31);
    for &kind in ALL_BACKENDS {
        let mut b = build(kind, H, D, BS, TOPK, 1);
        b.prefill(&q, &k, &v);
        let swappable = SWAPPABLE_BACKENDS.contains(&kind);
        match b.swap_out(0) {
            Ok(image) => {
                assert!(swappable, "{} swapped but is not registered swappable", b.name());
                assert_eq!(image.tokens(), 24, "{}", b.name());
                assert!(image.payload_bytes() > 0, "{}", b.name());
                assert_eq!(b.seq_len(), 24, "{}: swap_out must not mutate", b.name());
            }
            Err(_) => {
                assert!(!swappable, "{} is registered swappable but refused", b.name());
                assert_eq!(b.seq_len(), 24, "{}: failed swap must not corrupt", b.name());
            }
        }
    }
}

#[test]
fn swap_roundtrip_matches_never_swapped_twin() {
    // the tiered-KV resume contract at the backend level: snapshot
    // mid-decode, evict, restore the snapshot into a fresh backend, keep
    // decoding — every subsequent row must equal the never-swapped
    // twin's, bitwise (no re-ingest of the stream anywhere)
    let (n, split) = (37, 20);
    let q = rand_t(&[n, H, D], 32);
    let k = rand_t(&[n, H, D], 33);
    let v = rand_t(&[n, H, D], 34);
    for &kind in SWAPPABLE_BACKENDS {
        let mut twin = build(kind, H, D, BS, TOPK, 1);
        let mut victim = build(kind, H, D, BS, TOPK, 1);
        for t in 0..split {
            let a = victim.decode(row(&q, t), row(&k, t), row(&v, t));
            let b = twin.decode(row(&q, t), row(&k, t), row(&v, t));
            assert_eq!(a, b, "{} t={t}", twin.name());
        }
        let image = victim.swap_out(0).unwrap();
        let freed = victim.evict().unwrap();
        assert!(freed > 0, "{}", twin.name());
        let restored = victim.swap_in(&image).unwrap();
        let blocks = (split + BS - 1) / BS;
        assert_eq!(restored, blocks, "{}: restore must rebuild every block", twin.name());
        assert_eq!(victim.seq_len(), split, "{}", twin.name());
        for t in split..n {
            let a = victim.decode(row(&q, t), row(&k, t), row(&v, t));
            let b = twin.decode(row(&q, t), row(&k, t), row(&v, t));
            assert_eq!(a, b, "{} post-restore t={t}", twin.name());
        }
    }
}

#[test]
fn served_tokens_agree_within_each_math_family() {
    let prompt: Vec<i32> = (0..50).map(|i| (i * 7) % 48).collect();
    let serve = |kind: BackendKind| {
        let cfg = ServeCfg {
            block_size: BS,
            topk: TOPK,
            max_seq: 256,
            backend: kind,
            ..Default::default()
        };
        let engine = ServeEngine::new(ToyModel::new(48, H, D, 11), cfg);
        engine.generate(&prompt, 8).unwrap().0
    };
    let sparse_ref = serve(BackendKind::RecomputeMoba);
    for &kind in SPARSE_BACKENDS {
        assert_eq!(serve(kind), sparse_ref, "{}", kind.label());
    }
    assert_eq!(serve(BackendKind::CachedFull), serve(BackendKind::RecomputeFull));
}

#[test]
fn explicit_single_layer_spec_matches_the_implicit_stack() {
    // `--layers moba` (or `full`) with one entry must serve the same
    // tokens as the historical no-spec path, bitwise, on every backend
    let prompt: Vec<i32> = (0..50).map(|i| (i * 7) % 48).collect();
    let serve = |kind: BackendKind, layers: Vec<LayerKind>| {
        let cfg = ServeCfg {
            block_size: BS,
            topk: TOPK,
            max_seq: 256,
            backend: kind,
            layers,
            ..Default::default()
        };
        let engine = ServeEngine::new(ToyModel::new(48, H, D, 11), cfg);
        engine.generate(&prompt, 8).unwrap().0
    };
    for &kind in SPARSE_BACKENDS {
        assert_eq!(
            serve(kind, vec![LayerKind::Moba]),
            serve(kind, Vec::new()),
            "{}",
            kind.label()
        );
    }
    for kind in [BackendKind::CachedFull, BackendKind::RecomputeFull] {
        assert_eq!(
            serve(kind, vec![LayerKind::Full]),
            serve(kind, Vec::new()),
            "{}",
            kind.label()
        );
    }
}

#[test]
fn hybrid_stack_evict_resume_and_swap_match_never_evicted_twin() {
    // the serving-level resume contracts at L=4: a hybrid moba/full
    // session that is evicted + re-prefilled, and one that round-trips
    // through a per-layer swap bundle, must both finish with the
    // never-evicted twin's tokens, bitwise
    let layers = vec![LayerKind::Moba, LayerKind::Moba, LayerKind::Full, LayerKind::Moba];
    let cfg = ServeCfg {
        block_size: BS,
        topk: TOPK,
        max_seq: 256,
        backend: BackendKind::Paged,
        layers: layers.clone(),
        ..Default::default()
    };
    let engine = ServeEngine::new(ToyModel::stacked(48, H, D, 11, layers.len()), cfg);
    let prompt: Vec<i32> = (0..50).map(|i| (i * 3) % 48).collect();

    let mut twin = engine.start(&prompt, 16).unwrap();
    let mut evicted = engine.start(&prompt, 16).unwrap();
    let mut swapped = engine.start(&prompt, 16).unwrap();
    for _ in 0..5 {
        engine.step(&mut twin);
        engine.step(&mut evicted);
        engine.step(&mut swapped);
    }
    engine.evict_session(&mut evicted).unwrap();
    engine.resume_session(&mut evicted, None).unwrap();
    let (freed, bundle) = engine.swap_out_session(&mut swapped).unwrap();
    assert_eq!(bundle.layers(), layers.len(), "one swap image per layer");
    assert!(freed > 0);
    engine.swap_in_session(&mut swapped, None, &bundle).unwrap();
    while !twin.finished() {
        engine.step(&mut twin);
        engine.step(&mut evicted);
        engine.step(&mut swapped);
    }
    assert_eq!(evicted.output(), twin.output(), "re-prefill resume diverged");
    assert_eq!(swapped.output(), twin.output(), "swap restore diverged");
}
