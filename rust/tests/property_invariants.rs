//! Property-based tests (hand-rolled sweep harness — proptest is not
//! available offline; `sweep!` runs each property over many random
//! configurations and shrinks nothing but reports the failing seed).
//!
//! Invariants pinned here are the paper's §2.2 guarantees plus the
//! router/coordinator contracts.

use moba::coordinator::{RoutingPlan, StageSchedule};
use moba::sparse::{
    self, moba_gate, AttentionBackend, CachedDecodeBackend, DecodePolicy, FullAttention,
    MobaAttention,
};
use moba::tensor::Tensor;
use moba::util::rng::Rng;

/// Run `prop(seed)` for 40 derived seeds, reporting the failing one.
fn sweep(name: &str, mut prop: impl FnMut(u64)) {
    for trial in 0..40u64 {
        let seed = 0xBEEF ^ (trial * 0x9E37_79B9);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| prop(seed)));
        if let Err(e) = result {
            panic!("property '{name}' failed at seed {seed}: {e:?}");
        }
    }
}

fn rand_t(shape: &[usize], rng: &mut Rng) -> Tensor {
    let n: usize = shape.iter().product();
    Tensor::from_vec(shape, (0..n).map(|_| rng.normal_f32(1.0)).collect()).unwrap()
}

/// Random (n, h, d, block, topk) with n a multiple of block.
fn rand_cfg(rng: &mut Rng) -> (usize, usize, usize, usize, usize) {
    let block = [8, 16, 32][rng.range(0, 3)];
    let nb = rng.range(1, 7);
    let n = block * nb;
    let h = rng.range(1, 4);
    let d = [4, 8, 16][rng.range(0, 3)];
    let topk = rng.range(1, 5);
    (n, h, d, block, topk)
}

#[test]
fn prop_gate_causality_and_counts() {
    sweep("gate causality", |seed| {
        let mut rng = Rng::new(seed);
        let (n, h, d, block, topk) = rand_cfg(&mut rng);
        let q = rand_t(&[n, h, d], &mut rng);
        let k = rand_t(&[n, h, d], &mut rng);
        let g = moba_gate(&q, &k, block, topk);
        for hh in 0..h {
            for t in 0..n {
                let cur = t / block;
                assert!(g.get(hh, t, cur), "current block not selected");
                for i in cur + 1..n / block {
                    assert!(!g.get(hh, t, i), "future block selected");
                }
                let count = g.selected(hh, t).len();
                assert_eq!(count, topk.min(cur + 1), "selection count");
            }
        }
    });
}

#[test]
fn prop_moba_equals_full_when_covering() {
    sweep("covering topk == full attention", |seed| {
        let mut rng = Rng::new(seed);
        let block = [8, 16][rng.range(0, 2)];
        let nb = rng.range(1, 5);
        let (n, h, d) = (block * nb, rng.range(1, 3), 8);
        let q = rand_t(&[n, h, d], &mut rng);
        let k = rand_t(&[n, h, d], &mut rng);
        let v = rand_t(&[n, h, d], &mut rng);
        let a = sparse::moba_attention(&q, &k, &v, block, nb); // topk = nb covers
        let b = sparse::full_attention(&q, &k, &v);
        assert!(a.max_abs_diff(&b) < 1e-4, "diff {}", a.max_abs_diff(&b));
    });
}

#[test]
fn prop_output_rows_are_convex_combinations() {
    sweep("convexity", |seed| {
        let mut rng = Rng::new(seed);
        let (n, h, d, block, topk) = rand_cfg(&mut rng);
        let q = rand_t(&[n, h, d], &mut rng);
        let k = rand_t(&[n, h, d], &mut rng);
        // v constant per row -> every output must equal that constant
        let v = Tensor::ones(&[n, h, d]);
        let out = sparse::moba_attention(&q, &k, &v, block, topk);
        for &x in &out.data {
            assert!((x - 1.0).abs() < 1e-4, "not convex: {x}");
        }
    });
}

#[test]
fn prop_ungated_values_never_leak() {
    sweep("ungated value isolation", |seed| {
        let mut rng = Rng::new(seed);
        let block = 16;
        let nb = rng.range(3, 6);
        let n = block * nb;
        let (h, d) = (1, 8);
        let q = rand_t(&[n, h, d], &mut rng);
        let k = rand_t(&[n, h, d], &mut rng);
        let v = rand_t(&[n, h, d], &mut rng);
        let topk = 2;
        let g = moba_gate(&q, &k, block, topk);
        let t = n - 1;
        let ungated: Vec<usize> =
            (0..nb).filter(|&i| !g.get(0, t, i)).collect();
        if ungated.is_empty() {
            return;
        }
        let out1 = sparse::moba_attention(&q, &k, &v, block, topk);
        let mut v2 = v.clone();
        for j in ungated[0] * block..(ungated[0] + 1) * block {
            for dd in 0..d {
                v2.data[(j * h) * d + dd] += 1000.0;
            }
        }
        let out2 = sparse::moba_attention(&q, &k, &v2, block, topk);
        for dd in 0..d {
            let a = out1.data[(t * h) * d + dd];
            let b = out2.data[(t * h) * d + dd];
            assert!((a - b).abs() < 1e-4, "value leaked from ungated block");
        }
    });
}

#[test]
fn prop_fused_equals_two_pass_bitwise() {
    // the fused single-pass kernel must be indistinguishable from the
    // two-pass gate+attend path at every geometry, ragged lengths and
    // worker counts included
    sweep("fused == two-pass", |seed| {
        let mut rng = Rng::new(seed);
        let (n0, h, d, block, topk) = rand_cfg(&mut rng);
        let n = n0 + rng.range(0, block); // ragged final length
        let q = rand_t(&[n, h, d], &mut rng);
        let k = rand_t(&[n, h, d], &mut rng);
        let v = rand_t(&[n, h, d], &mut rng);
        let two_pass = sparse::moba_attention(&q, &k, &v, block, topk);
        for workers in [1usize, 3] {
            let fused = sparse::fused_moba_attention(&q, &k, &v, block, topk, workers);
            assert_eq!(fused.data, two_pass.data, "workers={workers}");
        }
    });
}

#[test]
fn prop_router_plan_partition() {
    sweep("router partitions gate pairs", |seed| {
        let mut rng = Rng::new(seed);
        let (n, h, d, block, topk) = rand_cfg(&mut rng);
        let q = rand_t(&[n, h, d], &mut rng);
        let k = rand_t(&[n, h, d], &mut rng);
        let g = moba_gate(&q, &k, block, topk);
        let mut total = 0;
        for hh in 0..h {
            let plan = RoutingPlan::build(&g, hh, block);
            total += plan.total_pairs();
            // every query appears in exactly one self segment
            let mut self_count = vec![0usize; n];
            for (i, b) in plan.blocks.iter().enumerate() {
                for &qq in &b.self_queries {
                    self_count[qq as usize] += 1;
                    assert_eq!(qq as usize / block, i);
                }
                for &qq in &b.hist_queries {
                    assert!(qq as usize / block > i, "history causality");
                }
            }
            assert!(self_count.iter().all(|&c| c == 1));
            // partials per query = gate row popcount
            for (t, &c) in plan.partials_per_query().iter().enumerate() {
                assert_eq!(c as usize, g.selected(hh, t).len());
            }
        }
        assert_eq!(total, g.total_selected());
    });
}

#[test]
fn prop_stage_schedule_total_conservation() {
    sweep("stage schedule covers every step exactly once", |seed| {
        let mut rng = Rng::new(seed);
        let total = rng.range(1, 200) as u64;
        let frac = rng.f64();
        let s = StageSchedule::hybrid("a", "b", total, frac).unwrap();
        assert_eq!(s.total_steps(), total);
        let mut a_count = 0u64;
        for step in 0..total {
            match s.artifact_for(step) {
                Some("a") => a_count += 1,
                Some("b") => {}
                _ => panic!("uncovered step {step}"),
            }
        }
        assert_eq!(a_count, ((total as f64) * frac).round() as u64);
        assert_eq!(s.artifact_for(total), None);
    });
}

/// Row `t` of a `[N, H, D]` tensor as a flat `[H * D]` slice.
fn row(t: &Tensor, i: usize) -> &[f32] {
    let w = t.shape[1] * t.shape[2];
    &t.data[i * w..(i + 1) * w]
}

/// First `n` rows of a `[N, H, D]` tensor.
fn prefix(t: &Tensor, n: usize) -> Tensor {
    let w = t.shape[1] * t.shape[2];
    Tensor::from_vec(&[n, t.shape[1], t.shape[2]], t.data[..n * w].to_vec()).unwrap()
}

#[test]
fn prop_cached_decode_matches_recompute_bitwise() {
    // The tentpole invariant: appending one token at a time through
    // CachedDecodeBackend must reproduce the batch kernels' last row at
    // EVERY length (including ragged, mid-block lengths) — within 1e-5,
    // and in fact bit-for-bit.
    sweep("cached decode == recompute", |seed| {
        let mut rng = Rng::new(seed);
        // kept small: every step recomputes the batch kernels over the
        // whole prefix (O(n^3) total per trial, debug profile)
        let block = [8, 16][rng.range(0, 2)];
        let nb = rng.range(1, 5);
        let h = rng.range(1, 3);
        let d = [4, 8][rng.range(0, 2)];
        let topk = rng.range(1, 4);
        let n = block * nb + rng.range(0, block); // ragged final length
        let q = rand_t(&[n, h, d], &mut rng);
        let k = rand_t(&[n, h, d], &mut rng);
        let v = rand_t(&[n, h, d], &mut rng);
        let mut dense = CachedDecodeBackend::new(h, d, block, topk, DecodePolicy::Full);
        let mut gated = CachedDecodeBackend::new(h, d, block, topk, DecodePolicy::Sparse);
        for t in 0..n {
            let got_dense = dense.decode(row(&q, t), row(&k, t), row(&v, t));
            let got_gated = gated.decode(row(&q, t), row(&k, t), row(&v, t));
            let (qp, kp, vp) = (prefix(&q, t + 1), prefix(&k, t + 1), prefix(&v, t + 1));
            let full = sparse::full_attention(&qp, &kp, &vp);
            let moba = sparse::moba_attention(&qp, &kp, &vp, block, topk);
            for (a, b) in got_dense.iter().zip(row(&full, t)) {
                assert!((a - b).abs() < 1e-5, "dense t={t}: {a} vs {b}");
            }
            for (a, b) in got_gated.iter().zip(row(&moba, t)) {
                assert!((a - b).abs() < 1e-5, "gated t={t}: {a} vs {b}");
            }
            assert_eq!(got_dense.as_slice(), row(&full, t), "dense not bit-identical t={t}");
            assert_eq!(got_gated.as_slice(), row(&moba, t), "gated not bit-identical t={t}");
        }
    });
}

#[test]
fn prop_prefill_boundary_is_invisible() {
    // Splitting a sequence into prefill + decode at ANY point must give
    // the same cached state as decoding token by token from the start,
    // and the same tokens as the recompute backends see.
    sweep("prefill/decode boundary invisible", |seed| {
        let mut rng = Rng::new(seed);
        let (n, h, d, block, topk) = rand_cfg(&mut rng);
        if n < 2 {
            return;
        }
        let split = rng.range(1, n);
        let q = rand_t(&[n, h, d], &mut rng);
        let k = rand_t(&[n, h, d], &mut rng);
        let v = rand_t(&[n, h, d], &mut rng);
        let mut with_prefill = CachedDecodeBackend::new(h, d, block, topk, DecodePolicy::Sparse);
        with_prefill.prefill(&prefix(&q, split), &prefix(&k, split), &prefix(&v, split));
        let mut stepwise = CachedDecodeBackend::new(h, d, block, topk, DecodePolicy::Sparse);
        for t in 0..split {
            stepwise.decode(row(&q, t), row(&k, t), row(&v, t));
        }
        for t in split..n {
            let a = with_prefill.decode(row(&q, t), row(&k, t), row(&v, t));
            let b = stepwise.decode(row(&q, t), row(&k, t), row(&v, t));
            assert_eq!(a, b, "t={t} split={split}");
        }
    });
}

#[test]
fn prop_recompute_backends_agree_with_batch_kernels() {
    // The trait's recompute baselines are exactly the batch kernels.
    sweep("recompute backends == batch kernels", |seed| {
        let mut rng = Rng::new(seed);
        let (n, h, d, block, topk) = rand_cfg(&mut rng);
        let q = rand_t(&[n, h, d], &mut rng);
        let k = rand_t(&[n, h, d], &mut rng);
        let v = rand_t(&[n, h, d], &mut rng);
        let mut full = FullAttention::new(h, d);
        let mut moba = MobaAttention::new(h, d, block, topk);
        let fb = full.prefill(&q, &k, &v);
        let mb = moba.prefill(&q, &k, &v);
        assert_eq!(fb.data, sparse::full_attention(&q, &k, &v).data);
        assert_eq!(mb.data, sparse::moba_attention(&q, &k, &v, block, topk).data);
        assert_eq!(full.seq_len(), n);
        assert_eq!(moba.seq_len(), n);
    });
}

#[test]
fn prop_full_attention_matches_row_softmax() {
    sweep("full attention row softmax", |seed| {
        let mut rng = Rng::new(seed);
        let n = rng.range(4, 48);
        let d = 8;
        let q = rand_t(&[n, 1, d], &mut rng);
        let k = rand_t(&[n, 1, d], &mut rng);
        let v = rand_t(&[n, 1, d], &mut rng);
        let out = sparse::full_attention(&q, &k, &v);
        // check one random row against direct softmax
        let t = rng.range(0, n);
        let scale = 1.0 / (d as f32).sqrt();
        let scores: Vec<f32> = (0..=t)
            .map(|j| {
                (0..d).map(|dd| q.at3(t, 0, dd) * k.at3(j, 0, dd)).sum::<f32>() * scale
            })
            .collect();
        let m = scores.iter().cloned().fold(f32::MIN, f32::max);
        let z: f32 = scores.iter().map(|s| (s - m).exp()).sum();
        for dd in 0..d {
            let expect: f32 = scores
                .iter()
                .enumerate()
                .map(|(j, s)| (s - m).exp() / z * v.at3(j, 0, dd))
                .sum();
            let got = out.at3(t, 0, dd);
            assert!((expect - got).abs() < 1e-4, "row {t} dim {dd}: {expect} vs {got}");
        }
    });
}
