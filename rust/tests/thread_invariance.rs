//! Thread-count invariance and fused-vs-two-pass parity.
//!
//! The determinism contract of `sparse::parallel`: worker counts NEVER
//! change results. Every kernel partitions work at (head, query)-row
//! granularity and computes each row with a fixed arithmetic order, so
//! `workers = 1` and `workers = ncpu` must agree *bit-for-bit* — on the
//! free kernels, on every backend's prefill/decode, and on the sharded
//! continuous scheduler's served tokens. The fused single-pass kernel is
//! additionally pinned bit-for-bit against the two-pass gate+attend path
//! it replaces on the hot path.

use moba::serve::{
    ContinuousScheduler, Request, RuntimeKind, SchedulerCfg, ServeCfg, ServeEngine, ToyModel,
};
use moba::sparse::{
    self, build_backend_par, default_workers, fused_moba_attention, moba_attention_par,
    BackendKind,
};
use moba::tensor::Tensor;
use moba::util::rng::Rng;

fn rand_t(shape: &[usize], seed: u64) -> Tensor {
    let mut rng = Rng::new(seed);
    let n: usize = shape.iter().product();
    Tensor::from_vec(shape, (0..n).map(|_| rng.normal_f32(1.0)).collect()).unwrap()
}

/// Worker counts worth exercising: serial, a couple of fixed counts that
/// don't divide typical row counts evenly, and whatever this box has.
fn worker_counts() -> Vec<usize> {
    let mut counts = vec![2, 3, 7];
    let ncpu = default_workers();
    if !counts.contains(&ncpu) {
        counts.push(ncpu);
    }
    counts
}

#[test]
fn free_kernels_are_worker_count_invariant() {
    // ragged N and heads that don't divide evenly into tiles
    for &(n, h, d, bs, topk, seed) in
        &[(70usize, 3usize, 8usize, 16usize, 2usize, 1u64), (128, 2, 16, 32, 3, 5)]
    {
        let q = rand_t(&[n, h, d], seed);
        let k = rand_t(&[n, h, d], seed + 1);
        let v = rand_t(&[n, h, d], seed + 2);
        let full_1 = sparse::full_attention(&q, &k, &v);
        let moba_1 = sparse::moba_attention(&q, &k, &v, bs, topk);
        let fused_1 = fused_moba_attention(&q, &k, &v, bs, topk, 1);
        for workers in worker_counts() {
            assert_eq!(
                sparse::full_attention_par(&q, &k, &v, workers).data,
                full_1.data,
                "full n={n} workers={workers}"
            );
            assert_eq!(
                moba_attention_par(&q, &k, &v, bs, topk, workers).data,
                moba_1.data,
                "moba n={n} workers={workers}"
            );
            assert_eq!(
                fused_moba_attention(&q, &k, &v, bs, topk, workers).data,
                fused_1.data,
                "fused n={n} workers={workers}"
            );
        }
    }
}

#[test]
fn fused_is_bitwise_equal_to_two_pass() {
    // the golden fused-vs-two-pass parity: same selections, same
    // streaming order, same arithmetic — so exactly the same bits,
    // across geometries including ragged tails and covering top-k
    for &(n, h, d, bs, topk, seed) in &[
        (64usize, 2usize, 8usize, 16usize, 2usize, 11u64),
        (53, 2, 8, 16, 2, 14),   // ragged tail block
        (96, 1, 16, 32, 3, 17),  // single head
        (48, 3, 8, 16, 3, 20),   // covering top-k (== full over blocks)
        (37, 2, 4, 8, 5, 23),    // topk == n_blocks (full coverage)
    ] {
        let q = rand_t(&[n, h, d], seed);
        let k = rand_t(&[n, h, d], seed + 1);
        let v = rand_t(&[n, h, d], seed + 2);
        let two_pass = sparse::moba_attention(&q, &k, &v, bs, topk);
        let fused = fused_moba_attention(&q, &k, &v, bs, topk, 1);
        assert_eq!(fused.data, two_pass.data, "n={n} h={h} bs={bs} topk={topk}");
    }
}

#[test]
fn backend_prefill_and_decode_are_worker_count_invariant() {
    let n = 45; // ragged
    let steps = 7;
    let (h, d, bs, topk) = (2, 8, 16, 2);
    let q = rand_t(&[n, h, d], 31);
    let k = rand_t(&[n, h, d], 32);
    let v = rand_t(&[n, h, d], 33);
    let w = h * d;
    for kind in [
        BackendKind::RecomputeFull,
        BackendKind::RecomputeMoba,
        BackendKind::CachedFull,
        BackendKind::CachedSparse,
        BackendKind::Fused,
        BackendKind::Paged,
    ] {
        let mut base = build_backend_par(kind, h, d, bs, topk, 1);
        let split = n - steps;
        let sub = |t: &Tensor| {
            Tensor::from_vec(&[split, h, d], t.data[..split * w].to_vec()).unwrap()
        };
        let base_prefill = base.prefill(&sub(&q), &sub(&k), &sub(&v));
        let base_rows: Vec<Vec<f32>> = (split..n)
            .map(|t| {
                base.decode(
                    &q.data[t * w..(t + 1) * w],
                    &k.data[t * w..(t + 1) * w],
                    &v.data[t * w..(t + 1) * w],
                )
            })
            .collect();
        for workers in worker_counts() {
            let mut b = build_backend_par(kind, h, d, bs, topk, workers);
            assert_eq!(
                b.prefill(&sub(&q), &sub(&k), &sub(&v)).data,
                base_prefill.data,
                "{} prefill workers={workers}",
                b.name()
            );
            for (i, t) in (split..n).enumerate() {
                let got = b.decode(
                    &q.data[t * w..(t + 1) * w],
                    &k.data[t * w..(t + 1) * w],
                    &v.data[t * w..(t + 1) * w],
                );
                assert_eq!(got, base_rows[i], "{} decode t={t} workers={workers}", b.name());
            }
        }
    }
}

#[test]
fn fused_backend_matches_cached_sparse_tokens() {
    // serving-level restatement: the fused backend emits exactly the
    // tokens of the cached-sparse (and recompute-moba) paths
    let prompt: Vec<i32> = (0..60).map(|i| (i * 11) % 48).collect();
    let engine = |backend: BackendKind, workers: usize| {
        ServeEngine::new(
            ToyModel::new(48, 2, 8, 11),
            ServeCfg {
                block_size: 16,
                topk: 2,
                max_seq: 256,
                backend,
                workers,
                ..Default::default()
            },
        )
    };
    let reference = engine(BackendKind::CachedSparse, 1).generate(&prompt, 10).unwrap().0;
    for workers in [1usize, 3] {
        let fused = engine(BackendKind::Fused, workers).generate(&prompt, 10).unwrap().0;
        assert_eq!(fused, reference, "workers={workers}");
    }
}

#[test]
fn sharded_scheduler_tokens_are_shard_count_invariant() {
    let engine = || {
        ServeEngine::new(
            ToyModel::new(48, 2, 8, 7),
            ServeCfg {
                block_size: 16,
                topk: 2,
                max_seq: 512,
                backend: BackendKind::Fused,
                workers: 1,
                ..Default::default()
            },
        )
    };
    let stream = || -> Vec<Request> {
        (0..8)
            .map(|i| {
                let prompt = (0..24 + i as i32).map(|j| (j * 3 + i as i32) % 48).collect();
                Request::new(i, prompt, 3 + (i as usize % 4), i as f64 * 0.08)
            })
            .collect()
    };
    let run = |decode_workers: usize| {
        let cfg = SchedulerCfg {
            max_in_flight: 4,
            decode_workers,
            runtime: RuntimeKind::TickLoop,
            ..SchedulerCfg::default()
        };
        let mut sched = ContinuousScheduler::new(engine(), cfg);
        let mut results = sched.run_stream(stream(), 0.05).unwrap();
        results.sort_by_key(|r| r.id);
        let outputs: Vec<Vec<i32>> = results.iter().map(|r| r.output.clone()).collect();
        (outputs, sched.stats.decode_steps_total, sched.worker_stats())
    };
    let (base_outputs, base_steps, _) = run(1);
    for decode_workers in [2usize, 4] {
        let (outputs, steps, workers) = run(decode_workers);
        assert_eq!(outputs, base_outputs, "decode_workers={decode_workers}");
        assert_eq!(steps, base_steps, "decode_workers={decode_workers}");
        assert_eq!(workers.len(), decode_workers);
        let stepped: usize = workers.iter().map(|w| w.decode_steps).sum();
        assert_eq!(stepped, steps, "per-shard steps must sum to the total");
    }
}

#[test]
fn persistent_runtime_tokens_match_tick_loop_bitwise() {
    // The serving-runtime determinism contract: the persistent
    // thread-per-core runtime (pre-spawned pinned workers, bounded
    // channels, work stealing) serves exactly the tokens of the legacy
    // per-tick scoped-thread loop, for every worker count and stealing
    // schedule — including while a bounded paged pool is evicting and
    // re-prefill-resuming sessions mid-stream.
    let stream = || -> Vec<Request> {
        (0..10)
            .map(|i| {
                // skewed decode budgets: every 4th request runs ~4x
                // longer, so multi-worker runs actually steal
                let prompt = (0..20 + 3 * i as i32).map(|j| (j * 5 + i as i32) % 48).collect();
                let max_new = if i % 4 == 0 { 12 } else { 3 };
                Request::new(i, prompt, max_new, i as f64 * 0.03)
            })
            .collect()
    };
    let engine = |backend: BackendKind, pool_blocks: usize| {
        ServeEngine::new(
            ToyModel::new(48, 2, 8, 7),
            ServeCfg {
                block_size: 16,
                topk: 2,
                max_seq: 512,
                backend,
                workers: 1,
                pool_blocks,
                ..Default::default()
            },
        )
    };
    // paged arm: barely one session's worth of blocks, so the pool
    // oversubscribes and the eviction/resume machinery churns constantly
    let max_need = {
        let solo = engine(BackendKind::Fused, 0);
        stream().iter().map(|r| solo.block_reserve(0, r.prompt.len() + r.max_new)).max().unwrap()
    };
    for (backend, pool_blocks) in [(BackendKind::Fused, 0usize), (BackendKind::Paged, max_need + 1)]
    {
        let run = |decode_workers: usize, runtime: RuntimeKind, steal: bool| {
            let cfg = SchedulerCfg {
                max_in_flight: 4,
                decode_workers,
                runtime,
                steal,
                ..SchedulerCfg::default()
            };
            let mut sched = ContinuousScheduler::new(engine(backend, pool_blocks), cfg);
            let mut results = sched.run_stream(stream(), 0.02).unwrap();
            results.sort_by_key(|r| r.id);
            let outputs: Vec<Vec<i32>> = results.iter().map(|r| r.output.clone()).collect();
            (outputs, sched.stats.decode_steps_total)
        };
        let (base_outputs, base_steps) = run(1, RuntimeKind::TickLoop, false);
        let mut counts = vec![1usize, 2];
        let ncpu = default_workers();
        if !counts.contains(&ncpu) {
            counts.push(ncpu);
        }
        for &decode_workers in &counts {
            for steal in [false, true] {
                let (outputs, steps) = run(decode_workers, RuntimeKind::Persistent, steal);
                assert_eq!(
                    outputs,
                    base_outputs,
                    "{} pool={pool_blocks} persistent workers={decode_workers} steal={steal}",
                    backend.label()
                );
                assert_eq!(
                    steps,
                    base_steps,
                    "{} pool={pool_blocks} persistent workers={decode_workers} steal={steal}",
                    backend.label()
                );
            }
            let (outputs, steps) = run(decode_workers, RuntimeKind::TickLoop, false);
            assert_eq!(
                outputs,
                base_outputs,
                "{} pool={pool_blocks} tick-loop workers={decode_workers}",
                backend.label()
            );
            assert_eq!(
                steps,
                base_steps,
                "{} pool={pool_blocks} tick-loop workers={decode_workers}",
                backend.label()
            );
        }
    }
}
