//! Integration tests over the PJRT runtime: artifact loading, kernel
//! execution parity, a short end-to-end training run, eval/logits paths,
//! checkpoint roundtrip through training, and failure injection.
//!
//! These need the `xla` feature (the whole file is compiled out without
//! it) and `artifacts/` (run `make artifacts` first); each test creates
//! its own Engine (PJRT CPU clients are cheap).
#![cfg(feature = "xla")]

use std::path::PathBuf;

use moba::coordinator::StageSchedule;
use moba::data::{Corpus, NeedleGen};
use moba::runtime::{checkpoint, manifest, Engine, ModelState};
use moba::tensor::{IntTensor, Tensor};
use moba::train::{LrSchedule, Trainer};
use moba::util::rng::Rng;

fn artifacts() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

fn engine() -> Engine {
    Engine::new(&artifacts()).expect("artifacts present — run `make artifacts`")
}

fn rand_nhd(n: usize, h: usize, d: usize, seed: u64) -> Tensor {
    let mut rng = Rng::new(seed);
    Tensor::from_vec(&[n, h, d], (0..n * h * d).map(|_| rng.normal_f32(1.0)).collect()).unwrap()
}

#[test]
fn manifest_validates_all_artifacts() {
    let e = engine();
    for art in e.manifest.artifacts.values() {
        manifest::validate(art).unwrap_or_else(|err| panic!("{}: {err}", art.name));
    }
}

#[test]
fn pallas_flash_kernel_matches_rust_reference() {
    let e = engine();
    let (q, k, v) = (rand_nhd(256, 2, 32, 1), rand_nhd(256, 2, 32, 2), rand_nhd(256, 2, 32, 3));
    let out = e.kernel("kernel_flash_n256", &q, &k, &v).unwrap();
    let expect = moba::sparse::full_attention(&q, &k, &v);
    assert!(out.max_abs_diff(&expect) < 2e-5);
}

#[test]
fn pallas_moba_kernel_matches_rust_reference() {
    // the L1 Pallas kernel (AOT through PJRT) against the independent
    // pure-Rust implementation: the strongest cross-language signal
    let e = engine();
    let (q, k, v) = (rand_nhd(256, 2, 32, 4), rand_nhd(256, 2, 32, 5), rand_nhd(256, 2, 32, 6));
    let out = e.kernel("kernel_moba_n256", &q, &k, &v).unwrap();
    let expect = moba::sparse::moba_attention(&q, &k, &v, 32, 3);
    assert!(out.max_abs_diff(&expect) < 2e-5);
}

#[test]
fn eval_loss_at_init_is_log_vocab() {
    let e = engine();
    let art = e.manifest.get("quickstart_eval").unwrap();
    let state = ModelState::init(art, 9).unwrap();
    let corpus = Corpus::for_vocab(art.model.vocab, 9);
    let (tokens, mask) = corpus.batch(9, 0, art.batch, art.seq);
    let losses = e.eval_losses("quickstart_eval", &state.params, &tokens, &mask).unwrap();
    let mean = losses.mean();
    let expect = (art.model.vocab as f32).ln();
    assert!((mean - expect).abs() < 0.3, "mean {mean} vs ln(V) {expect}");
}

#[test]
fn jnp_and_pallas_eval_graphs_agree() {
    // same geometry, same params, two attention implementations
    let e = engine();
    let art = e.manifest.get("quickstart_eval").unwrap();
    let state = ModelState::init(art, 11).unwrap();
    let corpus = Corpus::for_vocab(art.model.vocab, 11);
    let (tokens, mask) = corpus.batch(11, 0, art.batch, art.seq);
    let a = e.eval_losses("quickstart_eval", &state.params, &tokens, &mask).unwrap();
    let b = e.eval_losses("quickstart_eval_pallas", &state.params, &tokens, &mask).unwrap();
    assert!(a.max_abs_diff(&b) < 5e-4, "jnp vs pallas eval diff {}", a.max_abs_diff(&b));
}

#[test]
fn training_reduces_loss_e2e() {
    let e = engine();
    let steps = 12;
    let corpus = Corpus::for_vocab(256, 21);
    let lr = LrSchedule::new(3e-3, steps, 0.1, 0.1);
    let mut t = Trainer::new(&e, StageSchedule::single("quickstart_train", steps), lr, 21).unwrap();
    let s = t
        .run(|step| corpus.batch(21, step, 2, 256), |_| {})
        .unwrap();
    assert!(
        s.mean_last_quarter < s.losses[0] as f64 - 0.05,
        "loss did not decrease: {} -> {}",
        s.losses[0],
        s.mean_last_quarter
    );
}

#[test]
fn checkpoint_roundtrip_preserves_training() {
    let e = engine();
    let corpus = Corpus::for_vocab(256, 31);
    let lr = LrSchedule::new(3e-3, 4, 0.25, 0.1);
    let mut t = Trainer::new(&e, StageSchedule::single("quickstart_train", 4), lr, 31).unwrap();
    t.run(|step| corpus.batch(31, step, 2, 256), |_| {}).unwrap();

    let dir = std::env::temp_dir().join("moba_int_ckpt");
    let path = dir.join("s.ckpt");
    checkpoint::save(&t.state, &path).unwrap();
    let restored = checkpoint::load(&path).unwrap();
    assert_eq!(restored.step, t.state.step);

    // both states must produce identical eval losses
    let (tokens, mask) = corpus.batch(31, 999, 2, 256);
    let a = e.eval_losses("quickstart_eval", &t.state.params, &tokens, &mask).unwrap();
    let b = e.eval_losses("quickstart_eval", &restored.params, &tokens, &mask).unwrap();
    assert_eq!(a.data, b.data);
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn stage_switch_trains_through_both_executables() {
    // hybrid schedule at quickstart scale: moba for 3 steps, then the
    // pallas-eval twin can't train — use the same artifact twice to pin
    // the mechanics of switching (state continuity across executables)
    let e = engine();
    let corpus = Corpus::for_vocab(256, 41);
    let sched =
        StageSchedule::hybrid("quickstart_train", "quickstart_train", 6, 0.5).unwrap();
    let lr = LrSchedule::new(2e-3, 6, 0.2, 0.1);
    let mut t = Trainer::new(&e, sched, lr, 41).unwrap();
    let s = t.run(|step| corpus.batch(41, step, 2, 256), |_| {}).unwrap();
    assert_eq!(s.steps, 6);
    assert_eq!(t.state.step, 6);
}

#[test]
fn logits_argmax_is_stable_across_padding() {
    // causality: logits at the prompt tail must not depend on pad garbage
    let e = engine();
    let art = e.manifest.get("quickstart_logits").unwrap();
    let state = ModelState::init(art, 51).unwrap();
    let seq = art.seq;
    let mut toks_a = vec![0i32; seq];
    let mut toks_b = vec![7i32; seq];
    for i in 0..seq / 2 {
        let t = (i % 200) as i32;
        toks_a[i] = t;
        toks_b[i] = t;
    }
    let la = e
        .logits("quickstart_logits", &state.params, &IntTensor::from_vec(&[1, seq], toks_a).unwrap())
        .unwrap();
    let lb = e
        .logits("quickstart_logits", &state.params, &IntTensor::from_vec(&[1, seq], toks_b).unwrap())
        .unwrap();
    let v = art.model.vocab;
    let pos = seq / 2 - 1;
    for j in 0..v {
        let a = la.data[pos * v + j];
        let b = lb.data[pos * v + j];
        assert!((a - b).abs() < 1e-5, "pad leakage at logit {j}: {a} vs {b}");
    }
}

#[test]
fn wrong_kind_rejected() {
    let e = engine();
    let art = e.manifest.get("quickstart_eval").unwrap();
    let mut state = ModelState::init(art, 61).unwrap();
    let corpus = Corpus::for_vocab(256, 61);
    let (tokens, mask) = corpus.batch(61, 0, 2, 256);
    // eval artifact via train_step must fail cleanly
    assert!(e.train_step("quickstart_eval", &mut state, 1e-3, &tokens, &mask).is_err());
    // unknown artifact
    assert!(e.eval_losses("nonexistent", &state.params, &tokens, &mask).is_err());
}

#[test]
fn fused_train_k_matches_single_steps() {
    // the §Perf scan-fused graph must be semantically identical to K
    // single steps over the same batches and LR schedule
    let e = engine();
    let art = e.manifest.get("quickstart_train").unwrap();
    let artk = e.manifest.get("quickstart_train_k8").unwrap();
    let k = artk.k_steps;
    let corpus = Corpus::for_vocab(art.model.vocab, 81);
    let mut single = ModelState::init(art, 81).unwrap();
    let mut fused = single.clone();
    let lrs: Vec<f32> = (0..k).map(|i| 1e-3 + 1e-4 * i as f32).collect();

    // K single steps
    let mut single_losses = Vec::new();
    for (i, &lr) in lrs.iter().enumerate() {
        let (tokens, mask) = corpus.batch(81, i as u64, art.batch, art.seq);
        single_losses
            .push(e.train_step("quickstart_train", &mut single, lr, &tokens, &mask).unwrap());
    }

    // one fused call over the concatenated batches
    let mut toks = Vec::new();
    let mut masks = Vec::new();
    for i in 0..k {
        let (t, m) = corpus.batch(81, i as u64, art.batch, art.seq);
        toks.extend(t.data);
        masks.extend(m.data);
    }
    let tokens = IntTensor::from_vec(&[k, art.batch, art.seq], toks).unwrap();
    let mask_t = Tensor::from_vec(&[k, art.batch, art.seq - 1], masks).unwrap();
    let fused_losses = e
        .train_k_steps("quickstart_train_k8", &mut fused, &lrs, &tokens, &mask_t)
        .unwrap();

    assert_eq!(fused_losses.len(), k);
    for (a, b) in single_losses.iter().zip(&fused_losses) {
        assert!((a - b).abs() < 1e-4, "loss diverged: {a} vs {b}");
    }
    assert_eq!(single.step, fused.step);
    for (p, q) in single.params.iter().zip(&fused.params) {
        assert!(p.max_abs_diff(q) < 1e-4, "params diverged by {}", p.max_abs_diff(q));
    }
}

#[test]
fn serve_engine_generates() {
    let e = engine();
    let art = e.manifest.get("needle_s0_logits").unwrap();
    let state = ModelState::init(art, 71).unwrap();
    let serve = moba::serve::ArtifactServeEngine::new(
        &e,
        state.params,
        "needle_s0_logits",
        "needle_s0_full_logits",
    )
    .unwrap();
    let gen = NeedleGen::new(71);
    let sample = gen.eval_samples(1, 512, 0.5, 1).remove(0);
    let (out, stats) = serve.generate(&sample.tokens[..500], 4).unwrap();
    assert_eq!(out.len(), 4);
    assert!(stats.prefill_secs > 0.0);
    assert_eq!(stats.decode_steps, 3);
}
