//! Seeded scheduler fuzz: randomized arrival times, prompt lengths and
//! decode budgets (driven by the repo's own `Rng` — no `rand` dep),
//! asserting that the tokens each request is served are invariant to the
//! scheduler's decode shard count, to the decode runtime (legacy
//! tick-loop scoped threads vs the persistent thread-per-core workers,
//! with work stealing on or off) and to paged-pool capacity — a bounded
//! pool defers or *evicts* (LRU preemption + re-prefill resume when the
//! pool oversubscribes), and none of it may ever change what anyone
//! decodes — and equal to a solo single-session run of the same prompt
//! (the scheduler's interleaving is invisible). The overload arm layers
//! multi-tenant priority classes, deadline budgets and streaming pauses
//! over chaos + oversubscription: requests may be shed with a typed
//! error, but whatever completes still decodes the solo truth.

use moba::serve::{
    ContinuousScheduler, FaultPlan, Request, RequestResult, RuntimeKind, SchedulerCfg, ServeCfg,
    ServeEngine, ToyModel,
};
use moba::sparse::BackendKind;
use moba::util::rng::Rng;

const VOCAB: usize = 48;
const H: usize = 2;
const D: usize = 8;
const BS: usize = 16;

fn engine(backend: BackendKind, pool_blocks: usize) -> ServeEngine<ToyModel> {
    // honors MOBA_LAYERS (leniently) so the CI chaos matrix re-runs the
    // whole fuzz grid over a hybrid multi-layer session stack
    let layers = moba::serve::layers_from_env().unwrap_or_default();
    ServeEngine::new(
        ToyModel::stacked(VOCAB, H, D, 5, layers.len().max(1)),
        ServeCfg {
            block_size: BS,
            topk: 2,
            max_seq: 512,
            backend,
            workers: 1,
            pool_blocks,
            layers,
        },
    )
}

/// A paged engine over a 4-layer hybrid moba,moba,full,moba stack (same
/// geometry/seed as [`engine`], one block table per layer).
fn hybrid_engine(pool_blocks: usize) -> ServeEngine<ToyModel> {
    use moba::serve::LayerKind::{Full, Moba};
    let layers = vec![Moba, Moba, Full, Moba];
    ServeEngine::new(
        ToyModel::stacked(VOCAB, H, D, 5, layers.len()),
        ServeCfg {
            block_size: BS,
            topk: 2,
            max_seq: 512,
            backend: BackendKind::Paged,
            workers: 1,
            pool_blocks,
            layers,
        },
    )
}

/// One fuzzed arrival stream: bursty arrivals (exact-tie timestamps
/// included), ragged prompt lengths, ragged decode budgets.
fn stream(seed: u64, n: usize) -> Vec<Request> {
    let mut rng = Rng::new(seed);
    let mut t = 0.0f64;
    (0..n as u64)
        .map(|id| {
            // ~1/3 of requests arrive in a burst with the previous one
            if rng.range(0, 3) > 0 {
                t += rng.f64() * 0.04;
            }
            let len = 4 + rng.range(0, 44);
            let prompt = (0..len).map(|_| rng.range(0, VOCAB) as i32).collect();
            Request::new(id, prompt, 1 + rng.range(0, 8), t)
        })
        .collect()
}

fn serve(
    backend: BackendKind,
    pool_blocks: usize,
    decode_workers: usize,
    runtime: RuntimeKind,
    steal: bool,
    reqs: Vec<Request>,
) -> Vec<RequestResult> {
    let mut sched = ContinuousScheduler::new(
        engine(backend, pool_blocks),
        SchedulerCfg {
            max_in_flight: 4,
            decode_workers,
            runtime,
            steal,
            ..SchedulerCfg::default()
        },
    );
    let mut out = sched.run_stream(reqs, 0.005).unwrap();
    out.sort_by_key(|r| r.id);
    out
}

#[test]
fn fuzzed_streams_are_schedule_invariant() {
    for seed in [11u64, 23, 47] {
        let reqs = stream(seed, 9);
        // ground truth: each request decoded alone on a fresh engine
        let solo = engine(BackendKind::Fused, 0);
        let want: Vec<Vec<i32>> = reqs
            .iter()
            .map(|r| solo.generate(&r.prompt, r.max_new).unwrap().0)
            .collect();
        // worst-case paged reservation of any single request: a bounded
        // pool at least this big always makes progress (admission defers,
        // never errors)
        let max_need = reqs
            .iter()
            .map(|r| solo.block_reserve(0, r.prompt.len() + r.max_new))
            .max()
            .unwrap();
        let tight = max_need + 2; // room for ~1-2 sessions: heavy deferral
        let oversub = max_need + 1; // barely one session: constant eviction churn
        use RuntimeKind::{Persistent, TickLoop};
        for (backend, pool_blocks, decode_workers, runtime, steal) in [
            (BackendKind::Fused, 0, 1, TickLoop, false),
            (BackendKind::Fused, 0, 3, TickLoop, false),
            (BackendKind::Fused, 0, 3, Persistent, true),
            (BackendKind::Paged, 0, 1, Persistent, false),
            (BackendKind::Paged, 0, 4, TickLoop, false),
            (BackendKind::Paged, 0, 4, Persistent, true),
            (BackendKind::Paged, tight, 1, TickLoop, false),
            (BackendKind::Paged, tight, 3, Persistent, true),
            (BackendKind::Paged, oversub, 1, TickLoop, false),
            (BackendKind::Paged, oversub, 1, Persistent, true),
            (BackendKind::Paged, oversub, 3, Persistent, false),
            (BackendKind::Paged, oversub, 3, Persistent, true),
        ] {
            let got = serve(backend, pool_blocks, decode_workers, runtime, steal, reqs.clone());
            assert_eq!(got.len(), reqs.len(), "seed={seed} lost requests");
            for (g, w) in got.iter().zip(&want) {
                assert_eq!(
                    &g.output,
                    w,
                    "seed={seed} backend={} pool={pool_blocks} shards={decode_workers} \
                     runtime={} steal={steal} req={}",
                    backend.label(),
                    runtime.label(),
                    g.id
                );
            }
        }
    }
}

#[test]
fn fuzzed_hybrid_layer_streams_are_schedule_invariant() {
    // the multi-layer refactor under the fuzz grid: a 4-layer hybrid
    // moba,moba,full,moba stack served through both runtimes, with the
    // pool bounded so the layer-summed reservations oversubscribe and
    // whole session stacks are evicted / resumed together — none of
    // which may change what anyone decodes
    for seed in [19u64, 67] {
        let reqs = stream(seed, 8);
        let solo = hybrid_engine(0);
        let want: Vec<Vec<i32>> = reqs
            .iter()
            .map(|r| solo.generate(&r.prompt, r.max_new).unwrap().0)
            .collect();
        // block_reserve is layer-summed: the worst single request already
        // accounts for all four per-layer block tables
        let max_need = reqs
            .iter()
            .map(|r| solo.block_reserve(0, r.prompt.len() + r.max_new))
            .max()
            .unwrap();
        let oversub = max_need + 1; // barely one session: constant eviction churn
        use RuntimeKind::{Persistent, TickLoop};
        for (pool_blocks, decode_workers, runtime, steal) in [
            (0usize, 1usize, TickLoop, false),
            (0, 3, Persistent, true),
            (oversub, 1, TickLoop, false),
            (oversub, 1, Persistent, true),
            (oversub, 3, Persistent, true),
        ] {
            let mut sched = ContinuousScheduler::new(
                hybrid_engine(pool_blocks),
                SchedulerCfg {
                    max_in_flight: 4,
                    decode_workers,
                    runtime,
                    steal,
                    ..SchedulerCfg::default()
                },
            );
            let mut got = sched.run_stream(reqs.clone(), 0.005).unwrap();
            got.sort_by_key(|r| r.id);
            assert_eq!(got.len(), reqs.len(), "seed={seed} lost requests");
            for (g, w) in got.iter().zip(&want) {
                assert_eq!(
                    &g.output,
                    w,
                    "seed={seed} pool={pool_blocks} shards={decode_workers} runtime={} \
                     steal={steal} req={}",
                    runtime.label(),
                    g.id
                );
            }
        }
    }
}

#[test]
fn fuzzed_streams_are_fault_schedule_invariant() {
    // randomized fault schedules (seeded worker kills, stalls, alloc
    // failures) on top of the same fuzz grid: supervision re-homes the
    // dead shard's sessions through eviction/resume, and served tokens
    // must STILL be bitwise identical to the solo ground truth — across
    // steal on/off and pool oversubscription
    for seed in [13u64, 59, 97] {
        let reqs = stream(seed, 8);
        let solo = engine(BackendKind::Fused, 0);
        let want: Vec<Vec<i32>> = reqs
            .iter()
            .map(|r| solo.generate(&r.prompt, r.max_new).unwrap().0)
            .collect();
        let max_need = reqs
            .iter()
            .map(|r| solo.block_reserve(0, r.prompt.len() + r.max_new))
            .max()
            .unwrap();
        let oversub = max_need + 1;
        for (backend, pool_blocks, decode_workers, steal) in [
            (BackendKind::Fused, 0, 2, false),
            (BackendKind::Fused, 0, 3, true),
            (BackendKind::Paged, 0, 3, true),
            (BackendKind::Paged, oversub, 2, false),
            (BackendKind::Paged, oversub, 3, true),
        ] {
            // vary the plan per arm so each grid point sees different
            // faults; seeded plans always spare one worker
            let plan = FaultPlan::seeded(
                seed.wrapping_mul(31) ^ decode_workers as u64,
                decode_workers,
                48,
            );
            let mut sched = ContinuousScheduler::new(
                engine(backend, pool_blocks),
                SchedulerCfg {
                    max_in_flight: 4,
                    decode_workers,
                    runtime: RuntimeKind::Persistent,
                    steal,
                    chaos: Some(plan.clone()),
                    // generous: seeded stalls (tens of ms) must stay
                    // benign; only a wedged worker would trip this
                    barrier_deadline_secs: Some(5.0),
                    ..SchedulerCfg::default()
                },
            );
            let mut got = sched.run_stream(reqs.clone(), 0.005).unwrap();
            got.sort_by_key(|r| r.id);
            assert_eq!(got.len(), reqs.len(), "seed={seed} lost requests under chaos");
            for (g, w) in got.iter().zip(&want) {
                assert_eq!(
                    &g.output,
                    w,
                    "seed={seed} backend={} pool={pool_blocks} shards={decode_workers} \
                     steal={steal} faults={:?} req={}",
                    backend.label(),
                    plan.faults(),
                    g.id
                );
            }
            assert!(
                sched.stats.fault.worker_deaths <= plan.fatal_workers(),
                "seed={seed}: more deaths than scheduled faults"
            );
        }
    }
}

#[test]
fn fuzzed_priority_storms_survive_chaos_and_oversubscription() {
    // the overload composition: multi-tenant priority classes, deadline
    // budgets and streaming pauses on a barely-fits pool, with seeded
    // worker faults on top. Accounting must be exact — every request
    // either finishes or is shed with a typed error, nothing is lost —
    // every non-shed request must serve the solo ground truth bitwise,
    // and only scheduled fatal faults may kill workers.
    use moba::serve::Priority;
    for seed in [17u64, 101] {
        let mut rng = Rng::new(seed ^ 0x5702);
        let reqs: Vec<Request> = stream(seed, 10)
            .into_iter()
            .map(|r| {
                let pr = Priority::ALL[rng.weighted(&[0.4, 0.4, 0.2])];
                let mut r = r.with_priority(pr);
                if pr == Priority::Interactive && rng.f64() < 0.5 {
                    r = r.with_deadline(0.4 + rng.f64());
                }
                if rng.f64() < 0.3 {
                    r = r.with_pause_every(2 + rng.range(0, 3));
                }
                r
            })
            .collect();
        let solo = engine(BackendKind::Fused, 0);
        let want: Vec<Vec<i32>> = reqs
            .iter()
            .map(|r| solo.generate(&r.prompt, r.max_new).unwrap().0)
            .collect();
        let max_need = reqs
            .iter()
            .map(|r| solo.block_reserve(0, r.prompt.len() + r.max_new))
            .max()
            .unwrap();
        let oversub = max_need + 1; // barely one session resident at a time
        for (decode_workers, steal) in [(2usize, false), (3, true)] {
            let plan = FaultPlan::seeded(seed ^ decode_workers as u64, decode_workers, 48);
            let mut sched = ContinuousScheduler::new(
                engine(BackendKind::Paged, oversub),
                SchedulerCfg {
                    max_in_flight: 4,
                    decode_workers,
                    runtime: RuntimeKind::Persistent,
                    steal,
                    chaos: Some(plan.clone()),
                    barrier_deadline_secs: Some(5.0),
                    ..SchedulerCfg::default()
                },
            );
            let mut got = sched.run_stream(reqs.clone(), 0.005).unwrap();
            got.sort_by_key(|r| r.id);
            let shed: Vec<u64> = sched.sheds().iter().map(|(id, _)| *id).collect();
            assert_eq!(
                got.len() + shed.len(),
                reqs.len(),
                "seed={seed} shards={decode_workers}: requests lost (sheds {shed:?})"
            );
            for g in &got {
                assert!(
                    !shed.contains(&g.id),
                    "seed={seed}: request {} both finished and shed",
                    g.id
                );
                assert_eq!(
                    &g.output,
                    &want[g.id as usize],
                    "seed={seed} shards={decode_workers} steal={steal} faults={:?} req={}",
                    plan.faults(),
                    g.id
                );
            }
            assert!(
                sched.stats.fault.worker_deaths <= plan.fatal_workers(),
                "seed={seed}: more deaths than scheduled faults"
            );
        }
    }
}

#[test]
fn fuzzed_shared_prefix_streams_survive_chaos() {
    // copy-on-write forks + oversubscribed pool + a seeded worker kill:
    // recovery must re-fork the prefix and replay each orphan's own
    // tokens, bit-identical to the fault-free private-session truth
    for seed in [29u64, 83] {
        let mut rng = Rng::new(seed ^ 0xBEEF);
        let n_prefix = 24 + rng.range(0, 24);
        let prefix: Vec<i32> = (0..n_prefix).map(|_| rng.range(0, VOCAB) as i32).collect();
        let reqs = stream(seed, 6);
        let solo = engine(BackendKind::Fused, 0);
        let want: Vec<Vec<i32>> = reqs
            .iter()
            .map(|r| {
                let full: Vec<i32> = prefix.iter().chain(&r.prompt).copied().collect();
                solo.generate(&full, r.max_new).unwrap().0
            })
            .collect();
        let prefix_blocks = (prefix.len() + BS - 1) / BS;
        let max_fork_need = reqs
            .iter()
            .map(|r| solo.block_reserve(prefix.len(), r.prompt.len() + r.max_new))
            .max()
            .unwrap();
        let oversub = prefix_blocks + max_fork_need + 1;
        for pool_blocks in [0usize, oversub] {
            let mut sched = ContinuousScheduler::new(
                engine(BackendKind::Paged, pool_blocks),
                SchedulerCfg {
                    max_in_flight: 3,
                    decode_workers: 3,
                    runtime: RuntimeKind::Persistent,
                    steal: true,
                    chaos: Some(FaultPlan::seeded(seed, 3, 48)),
                    barrier_deadline_secs: Some(5.0),
                    ..SchedulerCfg::default()
                },
            );
            sched.set_shared_prefix(&prefix).unwrap();
            let mut got = sched.run_stream(reqs.clone(), 0.005).unwrap();
            got.sort_by_key(|r| r.id);
            for (g, w) in got.iter().zip(&want) {
                assert_eq!(
                    &g.output,
                    w,
                    "seed={seed} pool={pool_blocks} req={} diverged under chaos",
                    g.id
                );
            }
        }
    }
}

#[test]
fn fuzzed_shared_prefix_streams_are_schedule_invariant() {
    // same fuzz shape, but every request forks a shared system prompt
    // copy-on-write; ground truth is a private session over the
    // concatenated prompt
    for seed in [5u64, 71] {
        let mut rng = Rng::new(seed ^ 0xF0F0);
        let n_prefix = 24 + rng.range(0, 24);
        let prefix: Vec<i32> = (0..n_prefix).map(|_| rng.range(0, VOCAB) as i32).collect();
        let reqs = stream(seed, 7);
        let solo = engine(BackendKind::Fused, 0);
        let want: Vec<Vec<i32>> = reqs
            .iter()
            .map(|r| {
                let full: Vec<i32> = prefix.iter().chain(&r.prompt).copied().collect();
                solo.generate(&full, r.max_new).unwrap().0
            })
            .collect();
        // oversubscribed: the prefix plus barely one fork's tail — forked
        // sessions get evicted and re-forked off the surviving prefix
        let prefix_blocks = (prefix.len() + BS - 1) / BS;
        let max_fork_need = reqs
            .iter()
            .map(|r| solo.block_reserve(prefix.len(), r.prompt.len() + r.max_new))
            .max()
            .unwrap();
        let oversub = prefix_blocks + max_fork_need + 1;
        use RuntimeKind::{Persistent, TickLoop};
        for (pool_blocks, decode_workers, runtime, steal) in [
            (0usize, 1usize, TickLoop, false),
            (0, 3, TickLoop, false),
            (0, 3, Persistent, true),
            (64, 2, Persistent, true),
            (oversub, 1, TickLoop, false),
            (oversub, 1, Persistent, true),
            (oversub, 3, TickLoop, false),
            (oversub, 3, Persistent, true),
        ] {
            let mut sched = ContinuousScheduler::new(
                engine(BackendKind::Paged, pool_blocks),
                SchedulerCfg {
                    max_in_flight: 3,
                    decode_workers,
                    runtime,
                    steal,
                    ..SchedulerCfg::default()
                },
            );
            sched.set_shared_prefix(&prefix).unwrap();
            let mut got = sched.run_stream(reqs.clone(), 0.005).unwrap();
            got.sort_by_key(|r| r.id);
            for (g, w) in got.iter().zip(&want) {
                assert_eq!(
                    &g.output,
                    w,
                    "seed={seed} pool={pool_blocks} shards={decode_workers} runtime={} \
                     steal={steal} req={}",
                    runtime.label(),
                    g.id
                );
            }
        }
    }
}
