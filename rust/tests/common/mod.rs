//! Shared helpers for the backend test suites. The point of this module
//! is the ONE registry ([`ALL_BACKENDS`] + [`oracle`]) every
//! conformance-style test iterates: a future backend (per-head MoA
//! configs, SIMD variants, ...) gets golden-loop, invariant and
//! worker-parity coverage by adding one `BackendKind` entry here.
#![allow(dead_code)] // each test binary uses a subset of these helpers

use moba::sparse::{
    build_backend_par, full_attention, moba_attention, AttentionBackend, BackendKind,
};
use moba::tensor::Tensor;
use moba::util::rng::Rng;

/// Every registered backend kind, in CLI-label order.
pub const ALL_BACKENDS: &[BackendKind] = &[
    BackendKind::RecomputeFull,
    BackendKind::RecomputeMoba,
    BackendKind::CachedFull,
    BackendKind::CachedSparse,
    BackendKind::Fused,
    BackendKind::Paged,
];

/// The sparse (gated) backends — all of the same MoBA math, so their
/// outputs and served tokens must agree bit-for-bit with each other.
pub const SPARSE_BACKENDS: &[BackendKind] = &[
    BackendKind::RecomputeMoba,
    BackendKind::CachedSparse,
    BackendKind::Fused,
    BackendKind::Paged,
];

/// Backends whose incremental state can be evicted (blocks handed back
/// to a shared pool) and rebuilt bit-identically by re-ingesting the
/// same stream — the contract behind scheduler-level preemption. The
/// conformance harness checks that `AttentionBackend::evict` succeeds
/// exactly for these kinds and that evict → re-ingest → decode matches a
/// never-evicted twin bit-for-bit.
pub const EVICTABLE_BACKENDS: &[BackendKind] = &[BackendKind::Paged];

/// Backends whose incremental state can round-trip through the host swap
/// tier: `swap_out` snapshots the private tail byte-exact (checksummed),
/// `swap_in` restores it, and decode after restore matches a never-
/// swapped twin bit-for-bit — the contract behind tiered-KV preemption.
pub const SWAPPABLE_BACKENDS: &[BackendKind] = &[BackendKind::Paged];

/// The batch-kernel oracle a backend's outputs must reproduce: dense
/// backends mirror `full_attention`, everything else the two-pass MoBA
/// kernel.
pub fn oracle(
    kind: BackendKind,
    q: &Tensor,
    k: &Tensor,
    v: &Tensor,
    block: usize,
    topk: usize,
) -> Tensor {
    match kind {
        BackendKind::RecomputeFull | BackendKind::CachedFull => full_attention(q, k, v),
        _ => moba_attention(q, k, v, block, topk),
    }
}

/// Build one backend of the registry with an explicit worker count.
pub fn build(
    kind: BackendKind,
    heads: usize,
    head_dim: usize,
    block: usize,
    topk: usize,
    workers: usize,
) -> Box<dyn AttentionBackend> {
    build_backend_par(kind, heads, head_dim, block, topk, workers)
}

/// Deterministic normal-noise tensor.
pub fn rand_t(shape: &[usize], seed: u64) -> Tensor {
    let mut rng = Rng::new(seed);
    let n: usize = shape.iter().product();
    Tensor::from_vec(shape, (0..n).map(|_| rng.normal_f32(1.0)).collect()).unwrap()
}

/// Row `i` of a `[N, H, D]` tensor as a flat `[H * D]` slice.
pub fn row(t: &Tensor, i: usize) -> &[f32] {
    let w = t.shape[1] * t.shape[2];
    &t.data[i * w..(i + 1) * w]
}

/// First `n` rows of a `[N, H, D]` tensor.
pub fn prefix(t: &Tensor, n: usize) -> Tensor {
    let w = t.shape[1] * t.shape[2];
    Tensor::from_vec(&[n, t.shape[1], t.shape[2]], t.data[..n * w].to_vec()).unwrap()
}
