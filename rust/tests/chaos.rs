//! Chaos integration tests: kill, stall, slow and alloc-fail persistent
//! decode workers mid-run (seeded `FaultPlan` injection), poison the
//! shared pool lock, and assert the supervisor's recovery-as-eviction
//! path serves every request the *bitwise identical* tokens of a
//! fault-free run on the legacy tick-loop runtime — the oracle that
//! never sees chaos. Covers the plain stream, an oversubscribed paged
//! pool (recovery composes with eviction churn), copy-on-write
//! shared-prefix forks, survivable-by-design faults (`Slow` lag under
//! stealing, `PoisonPool` lock poisoning, `SwapCorrupt` host-tier image
//! rot demoting to re-prefill), and an env-seeded arm the CI chaos
//! matrix drives through `MOBA_CHAOS_SEED` × `MOBA_WORKERS` ×
//! `MOBA_SWAP_BLOCKS` × `MOBA_LAYERS` (a layer spec re-runs everything
//! here over hybrid multi-layer session stacks).

use moba::serve::{
    ContinuousScheduler, Fault, FaultKind, FaultPlan, Request, RequestResult, RuntimeKind,
    SchedulerCfg, ServeCfg, ServeEngine, ToyModel,
};
use moba::sparse::BackendKind;
use moba::util::rng::Rng;

const VOCAB: usize = 48;
const H: usize = 2;
const D: usize = 8;
const BS: usize = 16;

fn engine(backend: BackendKind, pool_blocks: usize) -> ServeEngine<ToyModel> {
    // honors MOBA_LAYERS (leniently) so the CI chaos matrix can re-run
    // every chaos test over a hybrid multi-layer session stack
    let layers = moba::serve::layers_from_env().unwrap_or_default();
    ServeEngine::new(
        ToyModel::stacked(VOCAB, H, D, 9, layers.len().max(1)),
        ServeCfg {
            block_size: BS,
            topk: 2,
            max_seq: 512,
            backend,
            workers: 1,
            pool_blocks,
            layers,
        },
    )
}

fn stream(seed: u64, n: usize) -> Vec<Request> {
    let mut rng = Rng::new(seed);
    let mut t = 0.0f64;
    (0..n as u64)
        .map(|id| {
            t += rng.f64() * 0.03;
            let len = 6 + rng.range(0, 40);
            let prompt = (0..len).map(|_| rng.range(0, VOCAB) as i32).collect();
            Request::new(id, prompt, 2 + rng.range(0, 7), t)
        })
        .collect()
}

/// Same shape but everything arrives at t=0: the batch fills to
/// `max_in_flight` on the first tick, so an early kill is guaranteed to
/// hit a worker that owns live sessions.
fn burst(seed: u64, n: usize) -> Vec<Request> {
    let mut reqs = stream(seed, n);
    for r in &mut reqs {
        r.arrival = 0.0;
    }
    reqs
}

/// Fault-free ground truth: the same stream on the tick-loop runtime
/// (which ignores chaos by construction).
fn oracle(backend: BackendKind, pool_blocks: usize, reqs: Vec<Request>) -> Vec<RequestResult> {
    let mut sched = ContinuousScheduler::new(
        engine(backend, pool_blocks),
        SchedulerCfg {
            max_in_flight: 4,
            runtime: RuntimeKind::TickLoop,
            ..SchedulerCfg::default()
        },
    );
    let mut out = sched.run_stream(reqs, 0.005).unwrap();
    out.sort_by_key(|r| r.id);
    out
}

fn chaos_sched(
    backend: BackendKind,
    pool_blocks: usize,
    decode_workers: usize,
    steal: bool,
    plan: FaultPlan,
) -> ContinuousScheduler<ToyModel> {
    ContinuousScheduler::new(
        engine(backend, pool_blocks),
        SchedulerCfg {
            max_in_flight: 4,
            decode_workers,
            runtime: RuntimeKind::Persistent,
            steal,
            chaos: Some(plan),
            // generous: seeded stalls are tens of ms and must stay
            // benign; only a truly wedged worker trips the deadline
            barrier_deadline_secs: Some(5.0),
            // the CI chaos matrix turns the host swap tier on via
            // MOBA_SWAP_BLOCKS so every fault above composes with
            // swap-out/swap-in churn; tokens must not change either way
            swap_blocks: moba::serve::scheduler::swap_blocks_from_env(),
            ..SchedulerCfg::default()
        },
    )
}

fn assert_parity(got: &[RequestResult], want: &[RequestResult], label: &str) {
    assert_eq!(got.len(), want.len(), "{label}: lost requests");
    for (g, w) in got.iter().zip(want) {
        assert_eq!(g.id, w.id, "{label}: id order");
        assert_eq!(g.output, w.output, "{label}: req {} tokens diverged", g.id);
    }
}

#[test]
fn killing_one_worker_matches_the_fault_free_oracle() {
    let reqs = burst(0xFA11, 8);
    let want = oracle(BackendKind::Fused, 0, reqs.clone());
    for steal in [false, true] {
        // tick 2: the first admission wave (tick 1, balanced 2/2 across
        // shards) is still decoding, so shard 1 dies owning sessions
        let plan =
            FaultPlan::new(vec![Fault { worker: 1, tick: 2, kind: FaultKind::Panic }]);
        let mut sched = chaos_sched(BackendKind::Fused, 0, 2, steal, plan);
        let mut got = sched.run_stream(reqs.clone(), 0.005).unwrap();
        got.sort_by_key(|r| r.id);
        assert_parity(&got, &want, &format!("steal={steal}"));
        let fs = sched.stats.fault;
        assert_eq!(fs.worker_deaths, 1, "steal={steal}: exactly one worker dies");
        assert!(fs.rehomed_sessions >= 1, "steal={steal}: dead shard had sessions to re-home");
        assert!(sched.idle(), "steal={steal}: every request retired");
    }
}

#[test]
fn worker_death_composes_with_pool_oversubscription() {
    let reqs = stream(0x0B5C, 8);
    let solo = engine(BackendKind::Fused, 0);
    let max_need = reqs
        .iter()
        .map(|r| solo.block_reserve(0, r.prompt.len() + r.max_new))
        .max()
        .unwrap();
    // barely one session fits: constant eviction churn even fault-free,
    // and recovery's quarantined sessions join the same preempted queue
    let oversub = max_need + 1;
    let want = oracle(BackendKind::Paged, oversub, reqs.clone());
    let plan = FaultPlan::new(vec![
        Fault { worker: 0, tick: 3, kind: FaultKind::AllocFail },
        Fault { worker: 2, tick: 9, kind: FaultKind::Panic },
    ]);
    let mut sched = chaos_sched(BackendKind::Paged, oversub, 3, true, plan);
    let mut got = sched.run_stream(reqs.clone(), 0.005).unwrap();
    got.sort_by_key(|r| r.id);
    assert_parity(&got, &want, "oversubscribed");
    let fs = sched.stats.fault;
    assert!(fs.worker_deaths >= 1, "at least the tick-3 fault must land");
    assert!(sched.idle());
}

#[test]
fn shared_prefix_forks_survive_worker_death() {
    let mut rng = Rng::new(0x5AFE);
    let prefix: Vec<i32> = (0..40).map(|_| rng.range(0, VOCAB) as i32).collect();
    let reqs = burst(0x5AFE, 6);

    let mut tick = ContinuousScheduler::new(
        engine(BackendKind::Paged, 0),
        SchedulerCfg {
            max_in_flight: 4,
            runtime: RuntimeKind::TickLoop,
            ..SchedulerCfg::default()
        },
    );
    tick.set_shared_prefix(&prefix).unwrap();
    let mut want = tick.run_stream(reqs.clone(), 0.005).unwrap();
    want.sort_by_key(|r| r.id);

    let plan = FaultPlan::new(vec![Fault { worker: 1, tick: 2, kind: FaultKind::Panic }]);
    let mut sched = chaos_sched(BackendKind::Paged, 0, 2, true, plan);
    sched.set_shared_prefix(&prefix).unwrap();
    let mut got = sched.run_stream(reqs, 0.005).unwrap();
    got.sort_by_key(|r| r.id);
    assert_parity(&got, &want, "shared-prefix");
    assert_eq!(sched.stats.fault.worker_deaths, 1);
}

#[test]
fn slow_workers_interleave_with_steals_without_spurious_deaths() {
    // survivable-by-design faults: repeated sub-deadline slowdowns on one
    // shard while stealing drains its deque. No worker may be declared
    // dead, no barrier may time out, and tokens must match the oracle.
    let reqs = burst(0x510, 8);
    let want = oracle(BackendKind::Fused, 0, reqs.clone());
    let plan = FaultPlan::new(vec![
        Fault { worker: 0, tick: 1, kind: FaultKind::Slow { millis: 8 } },
        Fault { worker: 0, tick: 2, kind: FaultKind::Slow { millis: 8 } },
        Fault { worker: 1, tick: 3, kind: FaultKind::Slow { millis: 4 } },
        Fault { worker: 0, tick: 4, kind: FaultKind::Slow { millis: 8 } },
    ]);
    let mut sched = chaos_sched(BackendKind::Fused, 0, 2, true, plan);
    let mut got = sched.run_stream(reqs, 0.005).unwrap();
    got.sort_by_key(|r| r.id);
    assert_parity(&got, &want, "slow");
    let fs = sched.stats.fault;
    assert_eq!(fs.worker_deaths, 0, "a slow worker is alive, not dead");
    assert_eq!(fs.barrier_timeouts, 0, "sub-deadline lag must not trip the barrier");
    assert!(sched.idle());
}

#[test]
fn poisoned_pool_lock_is_survivable() {
    // a chaos thread panics while holding the paged pool's write guard;
    // every later pool access must recover through util::sync's
    // poison-tolerant helpers and serve bitwise-identical tokens
    let reqs = burst(0xB01, 8);
    let want = oracle(BackendKind::Paged, 0, reqs.clone());
    let plan = FaultPlan::new(vec![
        Fault { worker: 1, tick: 2, kind: FaultKind::PoisonPool },
        Fault { worker: 0, tick: 5, kind: FaultKind::PoisonPool },
    ]);
    let mut sched = chaos_sched(BackendKind::Paged, 0, 2, true, plan);
    let mut got = sched.run_stream(reqs, 0.005).unwrap();
    got.sort_by_key(|r| r.id);
    assert_parity(&got, &want, "poisoned-pool");
    assert_eq!(sched.stats.fault.worker_deaths, 0, "poisoning is survivable by design");
    assert!(sched.idle());
}

#[test]
fn corrupted_swap_image_falls_back_to_reprefill_and_matches_oracle() {
    // the host tier's graceful-degradation contract: SwapCorrupt rots a
    // preempted session's image mid-run; its swap-in fails the checksum
    // and the scheduler silently re-prefills instead — tokens must stay
    // bitwise identical to the fault-free, swap-free tick-loop oracle
    let reqs = burst(0x5AB0, 8);
    let solo = engine(BackendKind::Fused, 0);
    let max_need = reqs
        .iter()
        .map(|r| solo.block_reserve(0, r.prompt.len() + r.max_new))
        .max()
        .unwrap();
    let oversub = max_need + 1; // constant eviction churn → images to rot
    let want = oracle(BackendKind::Paged, oversub, reqs.clone());
    // several corruption ticks so at least one lands while an image is
    // parked; worker index is irrelevant (applied scheduler-side)
    let plan = FaultPlan::new(vec![
        Fault { worker: 0, tick: 3, kind: FaultKind::SwapCorrupt },
        Fault { worker: 0, tick: 5, kind: FaultKind::SwapCorrupt },
        Fault { worker: 0, tick: 7, kind: FaultKind::SwapCorrupt },
        Fault { worker: 0, tick: 9, kind: FaultKind::SwapCorrupt },
    ]);
    let mut sched = ContinuousScheduler::new(
        engine(BackendKind::Paged, oversub),
        SchedulerCfg {
            max_in_flight: 4,
            decode_workers: 2,
            runtime: RuntimeKind::Persistent,
            steal: true,
            chaos: Some(plan),
            barrier_deadline_secs: Some(5.0),
            swap_blocks: 64,
            ..SchedulerCfg::default()
        },
    );
    let mut got = sched.run_stream(reqs, 0.005).unwrap();
    got.sort_by_key(|r| r.id);
    assert_parity(&got, &want, "swap-corrupt");
    let sw = &sched.stats.swap;
    assert!(sw.swap_outs > 0, "oversubscription with a tier must swap out");
    assert!(
        sw.fallbacks >= 1,
        "at least one corrupted image must demote to re-prefill (outs={} ins={})",
        sw.swap_outs,
        sw.swap_ins
    );
    assert!(
        sched.stats.eviction.resumes >= 1,
        "the corrupted session must have come back via re-prefill"
    );
    assert_eq!(sched.stats.fault.worker_deaths, 0, "corruption is survivable by design");
    assert!(sched.idle());
}

#[test]
fn env_seeded_chaos_is_survivable_and_reproducible() {
    // the CI chaos matrix drives this arm: MOBA_CHAOS_SEED picks the
    // fault schedule, MOBA_WORKERS (via default_workers) the shard count
    let seed = moba::serve::chaos::seed_from_env().unwrap_or(0xC0FFEE);
    let workers = moba::sparse::default_workers().clamp(2, 8);
    let reqs = stream(seed ^ 0xEC0, 8);
    let want = oracle(BackendKind::Fused, 0, reqs.clone());
    let plan = FaultPlan::seeded(seed, workers, 40);
    let deaths: Vec<usize> = (0..2)
        .map(|_| {
            let mut sched = chaos_sched(BackendKind::Fused, 0, workers, true, plan.clone());
            let mut got = sched.run_stream(reqs.clone(), 0.005).unwrap();
            got.sort_by_key(|r| r.id);
            assert_parity(&got, &want, &format!("seed={seed} workers={workers}"));
            assert!(sched.idle());
            sched.stats.fault.worker_deaths
        })
        .collect();
    assert!(
        deaths[0] <= plan.fatal_workers(),
        "seed={seed}: more deaths than the plan schedules"
    );
    // fatal faults fire at a deterministic tick: two identical runs see
    // the same death count
    assert_eq!(deaths[0], deaths[1], "seed={seed}: chaos not reproducible");
}
