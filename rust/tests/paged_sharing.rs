//! Shared-prefix parity for the paged pool: sessions forked off one
//! prefilled prefix must be indistinguishable — bit-for-bit in attention
//! outputs, token-for-token at the serving layer — from fully private
//! `KvCache` sessions fed the same streams, including after
//! copy-on-write divergence. Plus the memory shape the pool exists for:
//! S sessions over an N-token prefix hold O(N + S·tail) blocks, not
//! O(S·N).

mod common;

use common::{prefix, rand_t, row};
use moba::serve::{ServeCfg, ServeEngine, ToyModel};
use moba::sparse::{
    shared_pool, AttentionBackend, BackendKind, CachedDecodeBackend, DecodePolicy,
    FusedMobaAttention, PagedMobaAttention,
};

const H: usize = 2;
const D: usize = 8;
const BS: usize = 16;
const TOPK: usize = 2;

#[test]
fn forked_outputs_bitwise_match_private_caches_through_cow() {
    // 40-token prefix = 2 full blocks + an 8-token partial tail, so the
    // first post-fork append on EACH side goes through copy-on-write
    let (n, split) = (60, 40);
    let pq = rand_t(&[split, H, D], 1);
    let pk = rand_t(&[split, H, D], 2);
    let pv = rand_t(&[split, H, D], 3);

    let pool = shared_pool(BS, H, D, None);
    let mut parent = PagedMobaAttention::new(pool.clone(), TOPK);
    parent.prefill(&pq, &pk, &pv);
    let blocks_after_prefill = pool.read().unwrap().used_blocks();
    assert_eq!(blocks_after_prefill, 3);

    let mut forks = vec![parent.fork().unwrap(), parent.fork().unwrap()];
    assert_eq!(pool.read().unwrap().used_blocks(), 3, "fork must copy nothing");

    for (s, f) in forks.iter_mut().enumerate() {
        // divergent continuation per fork
        let q = rand_t(&[n, H, D], 100 + s as u64);
        let k = rand_t(&[n, H, D], 200 + s as u64);
        let v = rand_t(&[n, H, D], 300 + s as u64);
        // private references: fused AND cached-sparse, prefilled with the
        // same prefix then decoded with the same continuation
        let mut fused = FusedMobaAttention::new(H, D, BS, TOPK);
        fused.prefill(&pq, &pk, &pv);
        let mut cached = CachedDecodeBackend::new(H, D, BS, TOPK, DecodePolicy::Sparse);
        cached.prefill(&pq, &pk, &pv);
        for t in split..n {
            let got = f.decode(row(&q, t), row(&k, t), row(&v, t));
            assert_eq!(got, fused.decode(row(&q, t), row(&k, t), row(&v, t)), "s={s} t={t}");
            assert_eq!(got, cached.decode(row(&q, t), row(&k, t), row(&v, t)), "s={s} t={t}");
        }
        assert_eq!(f.seq_len(), n);
    }
    // the parent was never touched by either fork's writes: its next
    // decode still matches a private backend that saw only the prefix
    let q1 = rand_t(&[1, H, D], 901);
    let k1 = rand_t(&[1, H, D], 902);
    let v1 = rand_t(&[1, H, D], 903);
    let mut private = FusedMobaAttention::new(H, D, BS, TOPK);
    private.prefill(&pq, &pk, &pv);
    assert_eq!(
        parent.decode(&q1.data, &k1.data, &v1.data),
        private.decode(&q1.data, &k1.data, &v1.data),
        "fork writes leaked into the parent's prefix"
    );
}

#[test]
fn pool_memory_is_prefix_plus_tails_not_s_times_n() {
    // the acceptance criterion: S sessions sharing an N-token prefix
    // cost ceil(N/B) + S·tail blocks — O(N + S·tail), not O(S·N)
    let (n_prefix, extra, sessions) = (64usize, 8usize, 4usize);
    let total = n_prefix + extra;
    let q = rand_t(&[total, H, D], 41);
    let k = rand_t(&[total, H, D], 42);
    let v = rand_t(&[total, H, D], 43);

    let pool = shared_pool(BS, H, D, None);
    let mut parent = PagedMobaAttention::new(pool.clone(), TOPK);
    parent.prefill(&prefix(&q, n_prefix), &prefix(&k, n_prefix), &prefix(&v, n_prefix));

    let mut forks: Vec<_> = (0..sessions).map(|_| parent.fork().unwrap()).collect();
    for f in forks.iter_mut() {
        for t in n_prefix..total {
            f.decode(row(&q, t), row(&k, t), row(&v, t));
        }
    }
    let p = pool.read().unwrap();
    let shared_blocks = n_prefix / BS; // 4 — prefix held ONCE
    let tail_blocks = (extra + BS - 1) / BS; // 1 per session
    assert_eq!(p.used_blocks(), shared_blocks + sessions * tail_blocks);
    let private_blocks = sessions * ((total + BS - 1) / BS);
    assert!(
        p.used_blocks() * 2 < private_blocks,
        "not sharing: {} used vs {} private",
        p.used_blocks(),
        private_blocks
    );
    // bytes follow blocks
    let block_bytes = BS * H * D * 2 * std::mem::size_of::<f32>();
    assert_eq!(p.payload_bytes(), p.used_blocks() * block_bytes);
}

#[test]
fn evicted_forker_resumes_bitwise_under_surviving_shared_prefix() {
    // evict→resume parity: a fork evicted mid-decode, then rebuilt by
    // re-forking the prefix and re-ingesting its own tokens, must serve
    // rows bit-identical to a never-evicted twin — and the shared prefix
    // blocks must never leave the pool while the parent holds them
    let (n, split) = (64, 40); // 8-token shared partial tail
    let pq = rand_t(&[split, H, D], 11);
    let pk = rand_t(&[split, H, D], 12);
    let pv = rand_t(&[split, H, D], 13);
    let q = rand_t(&[n, H, D], 14);
    let k = rand_t(&[n, H, D], 15);
    let v = rand_t(&[n, H, D], 16);

    let pool = shared_pool(BS, H, D, None);
    let mut parent = PagedMobaAttention::new(pool.clone(), TOPK);
    parent.prefill(&pq, &pk, &pv);
    let prefix_blocks = pool.read().unwrap().used_blocks();
    assert_eq!(prefix_blocks, 3);

    let mut twin = parent.fork().unwrap();
    let mut victim = parent.fork().unwrap();
    let mid = 52; // both forks decode through the CoW boundary first
    for t in split..mid {
        let a = victim.decode(row(&q, t), row(&k, t), row(&v, t));
        let b = twin.decode(row(&q, t), row(&k, t), row(&v, t));
        assert_eq!(a, b, "pre-eviction t={t}");
    }
    let used_before = pool.read().unwrap().used_blocks();
    let freed = victim.evict().unwrap();
    // tokens [40, 52) span 2 blocks: the CoW tail copy + one fresh
    assert_eq!(freed, 2, "only the victim's private tail frees");
    assert_eq!(pool.read().unwrap().used_blocks(), used_before - freed);
    assert!(
        pool.read().unwrap().used_blocks() >= prefix_blocks,
        "shared prefix blocks must survive the forker's eviction"
    );

    // resume: re-fork the surviving prefix, re-ingest the victim's own
    // tokens through the same decode path, then keep decoding in step
    let mut resumed = parent.fork().unwrap();
    for t in split..mid {
        resumed.decode(row(&q, t), row(&k, t), row(&v, t));
    }
    for t in mid..n {
        let a = resumed.decode(row(&q, t), row(&k, t), row(&v, t));
        let b = twin.decode(row(&q, t), row(&k, t), row(&v, t));
        assert_eq!(a, b, "post-resume t={t}");
    }
    assert_eq!(resumed.seq_len(), n);
    // the parent's prefix is untouched: a fresh private backend fed the
    // same prefix decodes the next row identically to a new fork
    let q1 = rand_t(&[1, H, D], 17);
    let k1 = rand_t(&[1, H, D], 18);
    let v1 = rand_t(&[1, H, D], 19);
    let mut private = FusedMobaAttention::new(H, D, BS, TOPK);
    private.prefill(&pq, &pk, &pv);
    let mut fresh = parent.fork().unwrap();
    assert_eq!(
        fresh.decode(&q1.data, &k1.data, &v1.data),
        private.decode(&q1.data, &k1.data, &v1.data),
        "eviction corrupted the shared prefix bytes"
    );
}

#[test]
fn serving_layer_forks_match_private_sessions_token_for_token() {
    // engine-level restatement with real logits: forked sessions decode
    // exactly the tokens of private sessions over prefix ++ continuation
    let cfg = ServeCfg {
        block_size: BS,
        topk: TOPK,
        max_seq: 512,
        backend: BackendKind::Paged,
        ..Default::default()
    };
    let paged = ServeEngine::new(ToyModel::new(48, H, D, 9), cfg.clone());
    let private = ServeEngine::new(
        ToyModel::new(48, H, D, 9),
        ServeCfg { backend: BackendKind::CachedSparse, ..cfg },
    );
    let sys_prompt: Vec<i32> = (0..40).map(|i| (i * 3) % 48).collect();
    let parent = paged.start(&sys_prompt, 0).unwrap();
    for salt in 0..3i32 {
        let cont: Vec<i32> = (0..12).map(|i| (i * 5 + salt) % 48).collect();
        let mut forked = paged.fork_session(&parent, &cont, 8).unwrap();
        let mut tokens = Vec::new();
        while let Some(tok) = paged.step(&mut forked) {
            tokens.push(tok);
        }
        let full: Vec<i32> = sys_prompt.iter().chain(&cont).copied().collect();
        let want = private.generate(&full, 8).unwrap().0;
        assert_eq!(tokens, want, "salt={salt}");
    }
}
